//! Cluster tier: a zero-dependency TCP routing proxy over N backend
//! `hadacore serve` processes.
//!
//! One process — one listener, one batcher — is not a "millions of
//! users" story. This module is the scale-out shape production
//! inference stacks use: a routing front-end that keeps each shard's
//! batches **homogeneous** by routing on the batcher's own bucket
//! coordinates, with health checking, retriable failover, and
//! drain/restart of individual backends without dropping traffic.
//!
//! ```text
//!                        ┌────────────────────────────┐
//! client ── Request ───> │ proxy: conn reader + relay │ ── pipelined ──> backend 0
//!   ^                    │   route(n, dtype,          │ ── upstream  ──> backend 1
//!   └─── Response ────── │         epilogue, prologue)│ ── clients   ──> backend 2
//!        (demuxed by id) └────────────────────────────┘      (serve/client.rs)
//! ```
//!
//! Design notes:
//!
//! * **Routing key** = `(n, dtype, epilogue, prologue)` — exactly the
//!   wire-visible part of the batcher's `BucketKey`. Two requests with
//!   the same key land on the same shard (rendezvous hashing, below),
//!   so a shard's batcher sees deep homogeneous buckets instead of N
//!   shards each seeing a shallow slice of every bucket. Kernel choice
//!   and scale are deliberately *not* in the key: they don't change
//!   which bucket a request batches into on the shard.
//! * **Rendezvous (HRW) hashing** with a deterministic tie-break:
//!   every backend gets a score `mix(hash(key), backend)`; the highest
//!   eligible (healthy, not draining) score wins, an exact score tie
//!   falls to the least-loaded then lowest-index backend. Rendezvous
//!   hashing means removing a backend only remaps *its* keys — the
//!   others keep their shard (and their warm batches) through any
//!   failure or drain.
//! * **Pipelining**: one upstream [`Client`] per backend carries every
//!   proxied request; the wire protocol already streams responses out
//!   of order by id, so the proxy demuxes per upstream connection and
//!   per client connection without head-of-line coupling.
//! * **Failover**: an upstream `Busy`, a `Draining` error, or a dead
//!   upstream connection are all *retriable by contract*
//!   ([`ClientError::is_retriable`](super::client::ClientError) — the
//!   transform is pure, resubmitting cannot double-apply). The relay
//!   resubmits to the next backend in rendezvous order, up to
//!   [`ClusterConfig::max_attempts`] submissions; when no alternative
//!   shard is eligible it defers the retry by the server's
//!   `retry_after_us` hint instead of hot-spinning. Only when the
//!   attempt budget is spent does the client see a `Busy` (still
//!   retriable — the proxy never converts retriable into fatal).
//! * **Health**: a background prober pings every backend over the
//!   existing `Ping` frame each [`ClusterConfig::health_interval`];
//!   an unreachable backend is routed around until it answers again.
//!   A relay that observes a dead upstream marks the backend unhealthy
//!   immediately — feedback is not gated on the next probe tick.
//! * **Drain**: [`ClusterHandle::drain_backend`] stops *new* traffic
//!   to a shard while its in-flight requests complete normally;
//!   combined with the backend's own `Coordinator::drain` (whose
//!   `Draining` rejections the relay fails over), a backend restarts
//!   under load without a dropped request.
//!
//! The proxy data path allocates (frame clones for retries, per-entry
//! bookkeeping) — the zero-alloc contract lives on the *backends*,
//! whose serve path is unchanged. The proxy is I/O-bound fan-out; the
//! compute-bound work it routes is what the pooled path optimises.

use std::collections::HashSet;
use std::io::Write;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::Histogram;
use crate::hadamard::Prologue;
use crate::obs::trace::{self, Stage, TraceCtx};
use crate::quant::Epilogue;
use crate::util::error::{self as anyhow, anyhow};
use crate::util::f16::DType;

use super::client::{Client, PendingReply, Reply};
use super::wire::{
    decode_frame, write_frame, ErrorCode, Frame, WireError, WireRequest, WireStats,
    DEFAULT_MAX_FRAME_BYTES, MAX_TRACE_EVENTS,
};

/// Cluster-proxy configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Proxy bind address (`127.0.0.1:0` picks an ephemeral port — the
    /// bound address is on [`ClusterHandle::addr`]).
    pub addr: String,
    /// Backend `hadacore serve` addresses, in shard order.
    pub backends: Vec<String>,
    /// Client-facing connection bound; further connections get a
    /// connection-level `Busy` (id 0) and are closed — the same
    /// contract as the single-process server.
    pub max_conns: usize,
    /// Proxy-wide in-flight request cap (admitted to a backend,
    /// terminal reply not yet written back).
    pub max_inflight: usize,
    /// Frame-size cap for both client-facing and upstream frames.
    pub max_frame_bytes: u32,
    /// Client-conn reader poll quantum (shutdown-notice latency).
    pub poll_interval: Duration,
    /// Relay poll cadence while replies are in flight.
    pub relay_poll: Duration,
    /// Client-facing socket write timeout (a non-reading client cannot
    /// pin a relay thread past this).
    pub write_timeout: Duration,
    /// Backend health-probe period.
    pub health_interval: Duration,
    /// Total submission budget per request across all backends (first
    /// attempt + failovers + deferred retries). Spending it answers
    /// the client with a retriable `Busy`.
    pub max_attempts: usize,
    /// Backoff hint on proxy-originated `Busy` frames, and the floor
    /// of the deferred-retry wait.
    pub busy_retry_us: u32,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            addr: "127.0.0.1:0".to_string(),
            backends: Vec::new(),
            max_conns: 64,
            max_inflight: 1024,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            poll_interval: Duration::from_millis(50),
            relay_poll: Duration::from_micros(200),
            write_timeout: Duration::from_secs(5),
            health_interval: Duration::from_millis(50),
            max_attempts: 6,
            busy_retry_us: 1000,
        }
    }
}

/// The shard-routing key: the wire-visible coordinates of the
/// backend batcher's bucket. Requests with equal keys route to the
/// same healthy shard, so no shard ever assembles a mixed bucket from
/// proxy traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RouteKey {
    /// Transform size.
    pub n: u32,
    /// Payload dtype.
    pub dtype: DType,
    /// Fused rotate→quantize epilogue (tag + group).
    pub epilogue: Epilogue,
    /// Fused rotation prologue (seed included: rotated batches bucket
    /// per seed on the shard, so the route must too).
    pub prologue: Prologue,
}

impl RouteKey {
    /// The key of a wire request.
    pub fn of(req: &WireRequest) -> RouteKey {
        RouteKey {
            n: req.n,
            dtype: req.dtype,
            epilogue: req.epilogue,
            prologue: req.prologue,
        }
    }
}

/// Proxy-level counters (exposed through the proxy's `Stats` frame,
/// [`ClusterHandle::counters`], and — since every handle is a registered
/// [`crate::obs`] metric — the `hadacore_cluster_*` series of the text
/// exposition). Constructing one registers its metrics; stats frames and
/// `/metrics` scrapes read the same atomics.
#[derive(Debug)]
pub struct ClusterCounters {
    /// Client connections admitted.
    pub conns_accepted: Arc<AtomicU64>,
    /// Client connections shed at the pool bound.
    pub conns_rejected: Arc<AtomicU64>,
    /// Currently open client connections.
    pub conns_active: Arc<AtomicU64>,
    /// Requests currently in flight through the proxy.
    pub inflight: Arc<AtomicU64>,
    /// Requests forwarded to a backend (first attempts + retries).
    pub forwarded: Arc<AtomicU64>,
    /// Failover resubmissions (a retriable upstream outcome answered
    /// by submitting to another shard). The non-vacuity signal of the
    /// failover tests.
    pub retries: Arc<AtomicU64>,
    /// Retries the relay parked on a backoff hint because no
    /// alternative shard was eligible at that instant.
    pub deferrals: Arc<AtomicU64>,
    /// Responses relayed back to clients.
    pub responses: Arc<AtomicU64>,
    /// `Busy` frames the proxy answered on its own authority
    /// (admission shed, no eligible backend, attempt budget spent).
    pub busy_out: Arc<AtomicU64>,
    /// Error frames relayed or originated toward clients.
    pub errors_out: Arc<AtomicU64>,
    /// Health probes sent.
    pub health_probes: Arc<AtomicU64>,
    /// Health probes that failed (backend marked unhealthy).
    pub health_failures: Arc<AtomicU64>,
    /// Malformed client frames observed.
    pub protocol_errors: Arc<AtomicU64>,
    /// Dead spawned backends the supervisor respawned.
    pub restarts: Arc<AtomicU64>,
}

impl Default for ClusterCounters {
    fn default() -> Self {
        let r = crate::obs::registry();
        ClusterCounters {
            conns_accepted: r.counter(
                "hadacore_cluster_conns_accepted_total",
                "Client connections the proxy admitted.",
            ),
            conns_rejected: r.counter(
                "hadacore_cluster_conns_rejected_total",
                "Client connections shed at the proxy's pool bound.",
            ),
            conns_active: r.gauge(
                "hadacore_cluster_conns_active",
                "Currently open proxy client connections.",
            ),
            inflight: r.gauge(
                "hadacore_cluster_inflight",
                "Requests currently in flight through the proxy.",
            ),
            forwarded: r.counter(
                "hadacore_cluster_forwarded_total",
                "Requests forwarded to a backend (first attempts + retries).",
            ),
            retries: r.counter(
                "hadacore_cluster_retries_total",
                "Failover resubmissions to an alternative shard.",
            ),
            deferrals: r.counter(
                "hadacore_cluster_deferrals_total",
                "Retries parked on a backoff hint (no eligible shard).",
            ),
            responses: r.counter(
                "hadacore_cluster_responses_total",
                "Responses relayed back to proxy clients.",
            ),
            busy_out: r.counter(
                "hadacore_cluster_busy_out_total",
                "Busy frames the proxy answered on its own authority.",
            ),
            errors_out: r.counter(
                "hadacore_cluster_errors_out_total",
                "Error frames relayed or originated toward clients.",
            ),
            health_probes: r.counter(
                "hadacore_cluster_health_probes_total",
                "Backend health probes sent.",
            ),
            health_failures: r.counter(
                "hadacore_cluster_health_failures_total",
                "Health probes that marked a backend unhealthy.",
            ),
            protocol_errors: r.counter(
                "hadacore_cluster_protocol_errors_total",
                "Malformed client frames the proxy observed.",
            ),
            restarts: r.counter(
                "hadacore_cluster_restarts_total",
                "Dead spawned backends the supervisor respawned.",
            ),
        }
    }
}

/// Point-in-time view of one backend, for stats frames, bench records,
/// and tests.
#[derive(Clone, Debug)]
pub struct BackendSnapshot {
    /// Current upstream address.
    pub addr: String,
    /// Last health-probe verdict.
    pub healthy: bool,
    /// Whether new traffic is being routed away.
    pub draining: bool,
    /// Requests in flight on this shard right now.
    pub inflight: usize,
    /// Requests ever forwarded to this shard.
    pub forwarded: u64,
    /// Responses this shard returned.
    pub responses: u64,
    /// Elements transformed by those responses.
    pub elems: u64,
    /// Upstream latency percentiles in µs (submit → reply, proxy-side).
    pub p50_us: u64,
    /// 90th percentile, µs.
    pub p90_us: u64,
    /// 99th percentile, µs.
    pub p99_us: u64,
}

struct Backend {
    addr: Mutex<String>,
    client: Mutex<Option<Arc<Client>>>,
    healthy: AtomicBool,
    draining: AtomicBool,
    inflight: Arc<AtomicU64>,
    forwarded: Arc<AtomicU64>,
    responses: Arc<AtomicU64>,
    elems: Arc<AtomicU64>,
    latency: Arc<Histogram>,
    /// Route keys this shard has ever been handed (homogeneity
    /// bookkeeping: while the fleet is healthy, key sets are pairwise
    /// disjoint across shards — asserted by `cluster_e2e`).
    keys: Mutex<HashSet<RouteKey>>,
}

impl Backend {
    /// `index` labels this shard's registry series
    /// (`hadacore_cluster_backend_*{backend="index"}`); the label
    /// survives `replace_backend`, so a respawned shard keeps its
    /// series.
    fn new(index: usize, addr: String) -> Backend {
        let r = crate::obs::registry();
        let idx = index.to_string();
        Backend {
            addr: Mutex::new(addr),
            client: Mutex::new(None),
            healthy: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            inflight: r.labeled_gauge(
                "hadacore_cluster_backend_inflight",
                "Requests in flight on this shard.",
                "backend",
                &idx,
            ),
            forwarded: r.labeled_counter(
                "hadacore_cluster_backend_forwarded_total",
                "Requests ever forwarded to this shard.",
                "backend",
                &idx,
            ),
            responses: r.labeled_counter(
                "hadacore_cluster_backend_responses_total",
                "Responses this shard returned.",
                "backend",
                &idx,
            ),
            elems: r.labeled_counter(
                "hadacore_cluster_backend_elems_total",
                "Elements transformed by this shard's responses.",
                "backend",
                &idx,
            ),
            latency: r.labeled_histogram_us(
                "hadacore_cluster_backend_us",
                "Proxy-side upstream latency (submit to reply).",
                "backend",
                &idx,
            ),
            keys: Mutex::new(HashSet::new()),
        }
    }

    /// A usable upstream connection: the cached one if it can still
    /// carry traffic, else a fresh connect. `None` when the backend is
    /// unreachable. In-flight requests keep the old connection alive
    /// through their own `Arc`s, so replacing it never strands them.
    fn alive_client(&self, max_frame_bytes: u32) -> Option<Arc<Client>> {
        let mut slot = self.client.lock().unwrap();
        if let Some(c) = slot.as_ref() {
            if !c.is_dead() && c.shed_retry_us().is_none() {
                return Some(Arc::clone(c));
            }
        }
        *slot = None;
        let addr = self.addr.lock().unwrap().clone();
        match Client::connect_with(&addr, max_frame_bytes) {
            Ok(c) => {
                let c = Arc::new(c);
                *slot = Some(Arc::clone(&c));
                Some(c)
            }
            Err(_) => None,
        }
    }

    fn snapshot(&self) -> BackendSnapshot {
        BackendSnapshot {
            addr: self.addr.lock().unwrap().clone(),
            healthy: self.healthy.load(Ordering::Acquire),
            draining: self.draining.load(Ordering::Acquire),
            inflight: self.inflight.load(Ordering::Acquire) as usize,
            forwarded: self.forwarded.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            elems: self.elems.load(Ordering::Relaxed),
            p50_us: self.latency.percentile_us(50.0),
            p90_us: self.latency.percentile_us(90.0),
            p99_us: self.latency.percentile_us(99.0),
        }
    }
}

struct ClusterState {
    cfg: ClusterConfig,
    backends: Vec<Backend>,
    shutdown: AtomicBool,
    counters: ClusterCounters,
    conn_threads: Mutex<Vec<JoinHandle<()>>>,
}

// ---------------------------------------------------------------------
// Rendezvous routing.

/// SplitMix64 finaliser: a cheap, well-mixed 64-bit permutation.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn key_hash(key: &RouteKey) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    h.finish()
}

/// The rendezvous score of `backend` for a key hash: deterministic, so
/// the healthy-fleet key→shard map is a pure function (what the
/// homogeneity test pins), and independent per backend, so removing
/// one shard only remaps the keys it owned.
fn rendezvous_score(kh: u64, backend: usize) -> u64 {
    mix64(kh ^ mix64(backend as u64 + 1))
}

/// Highest-scoring eligible backend for `key`, excluding `exclude`.
/// Exact score ties (2^-64-rare, but the contract is deterministic)
/// break toward the least-loaded, then the lowest index.
fn route(state: &ClusterState, key: &RouteKey, exclude: &[usize]) -> Option<usize> {
    let kh = key_hash(key);
    let mut best: Option<(u64, usize)> = None;
    for (i, b) in state.backends.iter().enumerate() {
        if exclude.contains(&i)
            || !b.healthy.load(Ordering::Acquire)
            || b.draining.load(Ordering::Acquire)
        {
            continue;
        }
        let score = rendezvous_score(kh, i);
        best = Some(match best {
            None => (score, i),
            Some((bs, bi)) => {
                if score > bs {
                    (score, i)
                } else if score == bs
                    && b.inflight.load(Ordering::Acquire)
                        < state.backends[bi].inflight.load(Ordering::Acquire)
                {
                    (score, i)
                } else {
                    (bs, bi)
                }
            }
        });
    }
    best.map(|(_, i)| i)
}

/// Submit `req` to the best eligible backend not yet in `tried`,
/// walking down the rendezvous order past unreachable shards. Returns
/// the shard index and the in-flight handle; `None` when no eligible
/// shard accepted.
fn try_submit(
    state: &ClusterState,
    key: &RouteKey,
    req: &WireRequest,
    tried: &mut Vec<usize>,
) -> Option<(usize, PendingReply)> {
    loop {
        let i = route(state, key, tried)?;
        let backend = &state.backends[i];
        let Some(client) = backend.alive_client(state.cfg.max_frame_bytes) else {
            // connect refused: don't wait for the prober to notice
            backend.healthy.store(false, Ordering::Release);
            tried.push(i);
            continue;
        };
        match client.submit(req.clone()) {
            Ok(pending) => {
                backend.inflight.fetch_add(1, Ordering::AcqRel);
                backend.forwarded.fetch_add(1, Ordering::Relaxed);
                backend.keys.lock().unwrap().insert(*key);
                state.counters.forwarded.fetch_add(1, Ordering::Relaxed);
                return Some((i, pending));
            }
            Err(_) => {
                // retriable or not, this shard can't take the request
                // right now — fail sideways and let the relay (or the
                // attempt budget) decide how hard to keep trying
                tried.push(i);
                continue;
            }
        }
    }
}

// ---------------------------------------------------------------------
// The per-connection relay.

/// Where one proxied request currently is.
enum Leg {
    /// Submitted upstream; the reply will surface on `pending`.
    InFlight {
        backend: usize,
        pending: PendingReply,
        sent: Instant,
    },
    /// Parked on a backoff hint; re-routed when `at` passes.
    Deferred { at: Instant },
}

struct RelayEntry {
    /// The id the *client* used (restored onto every reply frame).
    client_id: u64,
    key: RouteKey,
    /// Retained clone for failover resubmission.
    req: WireRequest,
    /// Submissions + deferral cycles consumed so far.
    attempts: usize,
    /// Shards already tried this routing round.
    tried: Vec<usize>,
    leg: Leg,
}

type Entries = Arc<Mutex<Vec<RelayEntry>>>;

fn send_locked(half: &Mutex<TcpStream>, frame: &Frame) -> std::io::Result<()> {
    let mut s = half.lock().unwrap();
    write_frame(&mut *s, frame)?;
    s.flush()
}

/// Terminal-answer helper: write `frame` to the client unless the
/// connection already died; returns the updated deadness.
fn answer(write_half: &Mutex<TcpStream>, dead: bool, frame: &Frame) -> bool {
    if dead {
        return true;
    }
    if send_locked(write_half, frame).is_err() {
        let _ = write_half.lock().unwrap().shutdown(Shutdown::Both);
        return true;
    }
    false
}

fn relay_loop(
    state: &Arc<ClusterState>,
    write_half: &Arc<Mutex<TcpStream>>,
    entries: &Entries,
    reader_done: &Arc<AtomicBool>,
) {
    let mut dead = false;
    loop {
        // pull one actionable entry out of the list (reply arrived, or
        // a deferred retry came due), release the lock, then act — the
        // client write under `answer` can block up to the write
        // timeout and must not hold up the reader's submissions
        let entry = {
            let mut list = entries.lock().unwrap();
            let now = Instant::now();
            let mut found: Option<(usize, Option<Reply>)> = None;
            for (i, e) in list.iter().enumerate() {
                match &e.leg {
                    Leg::InFlight { pending, .. } => {
                        if let Some(r) = pending.try_wait() {
                            found = Some((i, Some(r)));
                            break;
                        }
                    }
                    Leg::Deferred { at } => {
                        if now >= *at {
                            found = Some((i, None));
                            break;
                        }
                    }
                }
            }
            found.map(|(i, reply)| (list.swap_remove(i), reply))
        };

        let Some((mut entry, reply)) = entry else {
            let idle = entries.lock().unwrap().is_empty();
            if idle && reader_done.load(Ordering::Acquire) {
                return;
            }
            if state.shutdown.load(Ordering::Acquire) {
                // teardown: resolve the books for whatever is still
                // parked; upstream replies for dropped entries are
                // discarded by the upstream client reader
                let drained: Vec<RelayEntry> =
                    entries.lock().unwrap().drain(..).collect();
                for e in drained {
                    if let Leg::InFlight { backend, .. } = e.leg {
                        state.backends[backend].inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                    state.counters.inflight.fetch_sub(1, Ordering::AcqRel);
                }
                return;
            }
            std::thread::sleep(state.cfg.relay_poll);
            continue;
        };

        match reply {
            // a deferred retry came due: clear the tried set (the
            // backoff is what made revisiting legitimate) and re-route
            None => {
                entry.tried.clear();
                dead = resubmit_or_fail(state, write_half, entries, entry, dead, 0);
            }
            Some(reply) => {
                let (backend, sent) = match entry.leg {
                    Leg::InFlight { backend, sent, .. } => (backend, sent),
                    Leg::Deferred { .. } => unreachable!("deferred legs carry no reply"),
                };
                state.backends[backend].inflight.fetch_sub(1, Ordering::AcqRel);
                match reply {
                    Reply::Response(mut r) => {
                        let us = sent.elapsed().as_micros() as u64;
                        let b = &state.backends[backend];
                        b.latency.record(us);
                        b.responses.fetch_add(1, Ordering::Relaxed);
                        b.elems.fetch_add(r.rows as u64 * r.n as u64, Ordering::Relaxed);
                        r.id = entry.client_id;
                        dead = answer(write_half, dead, &Frame::Response(r));
                        state.counters.responses.fetch_add(1, Ordering::Relaxed);
                        state.counters.inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                    // the retriable trio: per-request shed, graceful
                    // drain, dead upstream — fail over to another shard
                    Reply::Busy { retry_after_us } => {
                        entry.tried.push(backend);
                        dead = resubmit_or_fail(
                            state, write_half, entries, entry, dead, retry_after_us,
                        );
                    }
                    Reply::Error { code: ErrorCode::Draining, .. } => {
                        entry.tried.push(backend);
                        dead = resubmit_or_fail(state, write_half, entries, entry, dead, 0);
                    }
                    Reply::Disconnected => {
                        // dead upstream: route around it *now*, before
                        // the next probe tick confirms
                        state.backends[backend].healthy.store(false, Ordering::Release);
                        entry.tried.push(backend);
                        dead = resubmit_or_fail(state, write_half, entries, entry, dead, 0);
                    }
                    Reply::Error { code, msg } => {
                        dead = answer(
                            write_half,
                            dead,
                            &Frame::Error(WireError { id: entry.client_id, code, msg }),
                        );
                        state.counters.errors_out.fetch_add(1, Ordering::Relaxed);
                        state.counters.inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                    Reply::Pong
                    | Reply::Stats(_)
                    | Reply::StatsText(_)
                    | Reply::TraceDump(_) => {
                        dead = answer(
                            write_half,
                            dead,
                            &Frame::Error(WireError {
                                id: entry.client_id,
                                code: ErrorCode::ExecFailed,
                                msg: "unexpected upstream reply".to_string(),
                            }),
                        );
                        state.counters.errors_out.fetch_add(1, Ordering::Relaxed);
                        state.counters.inflight.fetch_sub(1, Ordering::AcqRel);
                    }
                }
            }
        }
    }
}

/// Failover step: resubmit to the next shard in rendezvous order, park
/// on the backoff hint when no shard is eligible, or — once the attempt
/// budget is spent — answer the client with a retriable `Busy`.
/// Returns the updated client-connection deadness.
fn resubmit_or_fail(
    state: &Arc<ClusterState>,
    write_half: &Arc<Mutex<TcpStream>>,
    entries: &Entries,
    mut entry: RelayEntry,
    dead: bool,
    hint_us: u32,
) -> bool {
    let hint = hint_us.max(state.cfg.busy_retry_us);
    if entry.attempts >= state.cfg.max_attempts {
        state.counters.busy_out.fetch_add(1, Ordering::Relaxed);
        state.counters.inflight.fetch_sub(1, Ordering::AcqRel);
        return answer(
            write_half,
            dead,
            &Frame::Busy { id: entry.client_id, retry_after_us: hint },
        );
    }
    entry.attempts += 1;
    match try_submit(state, &entry.key, &entry.req, &mut entry.tried) {
        Some((backend, pending)) => {
            state.counters.retries.fetch_add(1, Ordering::Relaxed);
            entry.leg = Leg::InFlight { backend, pending, sent: Instant::now() };
            entries.lock().unwrap().push(entry);
            dead
        }
        None => {
            state.counters.deferrals.fetch_add(1, Ordering::Relaxed);
            entry.leg = Leg::Deferred {
                at: Instant::now() + Duration::from_micros(u64::from(hint)),
            };
            entries.lock().unwrap().push(entry);
            dead
        }
    }
}

// ---------------------------------------------------------------------
// Client-facing connection handling.

fn stats_frame(state: &ClusterState, id: u64) -> Frame {
    let c = &state.counters;
    let mut counters: Vec<(String, u64)> = vec![
        ("proxy.backends".to_string(), state.backends.len() as u64),
        ("proxy.conns_active".to_string(), c.conns_active.load(Ordering::Acquire)),
        ("proxy.inflight".to_string(), c.inflight.load(Ordering::Acquire)),
        ("proxy.forwarded".to_string(), c.forwarded.load(Ordering::Relaxed)),
        ("proxy.retries".to_string(), c.retries.load(Ordering::Relaxed)),
        ("proxy.deferrals".to_string(), c.deferrals.load(Ordering::Relaxed)),
        ("proxy.responses".to_string(), c.responses.load(Ordering::Relaxed)),
        ("proxy.busy_out".to_string(), c.busy_out.load(Ordering::Relaxed)),
        ("proxy.errors_out".to_string(), c.errors_out.load(Ordering::Relaxed)),
        ("proxy.health_probes".to_string(), c.health_probes.load(Ordering::Relaxed)),
        ("proxy.health_failures".to_string(), c.health_failures.load(Ordering::Relaxed)),
        ("proxy.restarts".to_string(), c.restarts.load(Ordering::Relaxed)),
    ];
    let mut report = String::from("cluster proxy\n");
    for (i, b) in state.backends.iter().enumerate() {
        let s = b.snapshot();
        counters.push((format!("backend{i}.healthy"), u64::from(s.healthy)));
        counters.push((format!("backend{i}.draining"), u64::from(s.draining)));
        counters.push((format!("backend{i}.inflight"), s.inflight as u64));
        counters.push((format!("backend{i}.forwarded"), s.forwarded));
        counters.push((format!("backend{i}.responses"), s.responses));
        counters.push((format!("backend{i}.elems"), s.elems));
        counters.push((format!("backend{i}.p50_us"), s.p50_us));
        counters.push((format!("backend{i}.p90_us"), s.p90_us));
        counters.push((format!("backend{i}.p99_us"), s.p99_us));
        report.push_str(&format!(
            "backend {i} {} healthy={} draining={} inflight={} forwarded={} \
             responses={} p50={}us p90={}us p99={}us\n",
            s.addr,
            s.healthy,
            s.draining,
            s.inflight,
            s.forwarded,
            s.responses,
            s.p50_us,
            s.p90_us,
            s.p99_us,
        ));
    }
    Frame::Stats(WireStats { id, counters, report })
}

fn handle_conn(state: &Arc<ClusterState>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(state.cfg.poll_interval));
    let _ = stream.set_write_timeout(Some(state.cfg.write_timeout));
    if let Ok(write_stream) = stream.try_clone() {
        let write_half = Arc::new(Mutex::new(write_stream));
        conn_loop(state, stream, &write_half);
    }
    state.counters.conns_active.fetch_sub(1, Ordering::AcqRel);
}

fn conn_loop(
    state: &Arc<ClusterState>,
    mut reader: TcpStream,
    write_half: &Arc<Mutex<TcpStream>>,
) {
    let entries: Entries = Arc::new(Mutex::new(Vec::new()));
    let reader_done = Arc::new(AtomicBool::new(false));
    let relay = {
        let state = Arc::clone(state);
        let write_half = Arc::clone(write_half);
        let entries = Arc::clone(&entries);
        let reader_done = Arc::clone(&reader_done);
        std::thread::Builder::new()
            .name("hadacore-cluster-relay".to_string())
            .spawn(move || relay_loop(&state, &write_half, &entries, &reader_done))
    };
    let relay = match relay {
        Ok(r) => r,
        Err(_) => return,
    };

    // incremental framing, exactly like the single-process server: the
    // read timeout is the shutdown-poll quantum and consumes nothing
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    'conn: loop {
        loop {
            match decode_frame(&buf, state.cfg.max_frame_bytes) {
                Ok(Some((frame, used))) => {
                    buf.drain(..used);
                    if !handle_frame(state, write_half, &entries, frame) {
                        break 'conn;
                    }
                }
                Ok(None) => break,
                Err(msg) => {
                    state.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = send_locked(
                        write_half,
                        &Frame::Error(WireError { id: 0, code: ErrorCode::Malformed, msg }),
                    );
                    break 'conn;
                }
            }
        }
        if state.shutdown.load(Ordering::Acquire) {
            break;
        }
        use std::io::Read;
        match reader.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    reader_done.store(true, Ordering::Release);
    let _ = reader.shutdown(Shutdown::Both);
    let _ = relay.join();
}

/// Dispatch one client frame; `false` ends the connection.
fn handle_frame(
    state: &Arc<ClusterState>,
    write_half: &Arc<Mutex<TcpStream>>,
    entries: &Entries,
    frame: Frame,
) -> bool {
    match frame {
        Frame::Ping { id } => send_locked(write_half, &Frame::Pong { id }).is_ok(),
        Frame::StatsRequest { id } => {
            send_locked(write_half, &stats_frame(state, id)).is_ok()
        }
        Frame::StatsTextRequest { id } => {
            // the proxy's own registry: cluster counters, per-backend
            // series, plus whatever else lives in this process
            let text = crate::obs::registry().render();
            send_locked(write_half, &Frame::StatsText { id, text }).is_ok()
        }
        Frame::TraceRequest { id, trace: want } => {
            // merge this process's rings with every reachable backend's,
            // re-sorted so the cross-process chain reads in event order.
            // Drains are snapshots, so a backend sharing this process
            // (the self-hosted fleet) reports the same rings again —
            // dedup identical events after the full-key sort
            let mut events = trace::drain_trace(want);
            for b in &state.backends {
                if let Some(client) = b.alive_client(state.cfg.max_frame_bytes) {
                    if let Ok(mut evs) = client.trace_dump(want) {
                        events.append(&mut evs);
                    }
                }
            }
            events.sort_by_key(|e| (e.t_us, e.stage as u8, e.trace, e.arg));
            events.dedup();
            events.truncate(MAX_TRACE_EVENTS);
            send_locked(write_half, &Frame::TraceDump { id, events }).is_ok()
        }
        Frame::Request(mut req) => {
            let client_id = req.id;
            // adopt the client's trace id or sample one here; the id
            // rides the flag-gated wire extension on every forwarded
            // attempt, so backend spans join this request's chain
            let trace_ctx = if req.trace != 0 {
                TraceCtx(req.trace)
            } else {
                trace::sample()
            };
            req.trace = trace_ctx.0;
            trace::event(trace_ctx, Stage::ProxyAdmit, req.rows);
            if state.counters.inflight.load(Ordering::Acquire)
                >= state.cfg.max_inflight as u64
            {
                state.counters.busy_out.fetch_add(1, Ordering::Relaxed);
                return send_locked(
                    write_half,
                    &Frame::Busy { id: client_id, retry_after_us: state.cfg.busy_retry_us },
                )
                .is_ok();
            }
            let key = RouteKey::of(&req);
            let mut tried = Vec::new();
            match try_submit(state, &key, &req, &mut tried) {
                Some((backend, pending)) => {
                    state.counters.inflight.fetch_add(1, Ordering::AcqRel);
                    entries.lock().unwrap().push(RelayEntry {
                        client_id,
                        key,
                        req,
                        attempts: 1,
                        tried,
                        leg: Leg::InFlight { backend, pending, sent: Instant::now() },
                    });
                    true
                }
                None => {
                    // no shard reachable right now: still a retriable
                    // outcome from where the client stands
                    state.counters.busy_out.fetch_add(1, Ordering::Relaxed);
                    send_locked(
                        write_half,
                        &Frame::Busy {
                            id: client_id,
                            retry_after_us: state.cfg.busy_retry_us,
                        },
                    )
                    .is_ok()
                }
            }
        }
        // server-to-client frames arriving from a client are protocol
        // violations; drop the connection like the server would
        Frame::Response(_)
        | Frame::Error(_)
        | Frame::Busy { .. }
        | Frame::Pong { .. }
        | Frame::Stats(_)
        | Frame::StatsText { .. }
        | Frame::TraceDump { .. } => {
            state.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
            false
        }
    }
}

// ---------------------------------------------------------------------
// Acceptor + health prober + handle.

fn accept_loop(listener: TcpListener, state: &Arc<ClusterState>) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) => {
                if state.shutdown.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if state.shutdown.load(Ordering::Acquire) {
            return;
        }
        {
            let mut threads = state.conn_threads.lock().unwrap();
            let mut live = Vec::with_capacity(threads.len());
            for h in threads.drain(..) {
                if h.is_finished() {
                    let _ = h.join();
                } else {
                    live.push(h);
                }
            }
            *threads = live;
        }
        if state.counters.conns_active.load(Ordering::Acquire)
            >= state.cfg.max_conns as u64
        {
            state.counters.conns_rejected.fetch_add(1, Ordering::Relaxed);
            let mut s = stream;
            let busy = Frame::Busy { id: 0, retry_after_us: state.cfg.busy_retry_us };
            let _ = s.write_all(&busy.encode());
            let _ = s.shutdown(Shutdown::Both);
            continue;
        }
        state.counters.conns_active.fetch_add(1, Ordering::AcqRel);
        state.counters.conns_accepted.fetch_add(1, Ordering::Relaxed);
        let conn_state = Arc::clone(state);
        match std::thread::Builder::new()
            .name("hadacore-cluster-conn".to_string())
            .spawn(move || handle_conn(&conn_state, stream))
        {
            Ok(h) => state.conn_threads.lock().unwrap().push(h),
            Err(_) => {
                state.counters.conns_active.fetch_sub(1, Ordering::AcqRel);
            }
        }
    }
}

/// One probe sweep over the fleet: ping each backend over its upstream
/// connection (reconnecting if needed) and set its health bit.
fn probe_all(state: &ClusterState) {
    for b in &state.backends {
        state.counters.health_probes.fetch_add(1, Ordering::Relaxed);
        let ok = b
            .alive_client(state.cfg.max_frame_bytes)
            .map(|c| c.ping().is_ok())
            .unwrap_or(false);
        let was = b.healthy.swap(ok, Ordering::AcqRel);
        if !ok && was {
            state.counters.health_failures.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn health_loop(state: &Arc<ClusterState>) {
    while !state.shutdown.load(Ordering::Acquire) {
        probe_all(state);
        // sleep in poll-sized steps so shutdown isn't gated on a full
        // health interval
        let mut left = state.cfg.health_interval;
        while left > Duration::ZERO && !state.shutdown.load(Ordering::Acquire) {
            let step = left.min(Duration::from_millis(10));
            std::thread::sleep(step);
            left = left.saturating_sub(step);
        }
    }
}

/// Handle to a running cluster proxy; dropping it shuts the proxy
/// down (backends are *not* owned and keep running).
pub struct ClusterHandle {
    addr: SocketAddr,
    state: Arc<ClusterState>,
    accept_thread: Option<JoinHandle<()>>,
    health_thread: Option<JoinHandle<()>>,
}

/// Bind the proxy and start routing to `cfg.backends`. Probes every
/// backend once before returning, so a healthy fleet routes from the
/// first request.
pub fn cluster(cfg: ClusterConfig) -> anyhow::Result<ClusterHandle> {
    if cfg.backends.is_empty() {
        return Err(anyhow!("cluster needs at least one backend"));
    }
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| anyhow!("bind {}: {e}", cfg.addr))?;
    let addr = listener.local_addr().map_err(|e| anyhow!("local_addr: {e}"))?;
    let backends = cfg
        .backends
        .iter()
        .enumerate()
        .map(|(i, addr)| Backend::new(i, addr.clone()))
        .collect();
    let state = Arc::new(ClusterState {
        cfg,
        backends,
        shutdown: AtomicBool::new(false),
        counters: ClusterCounters::default(),
        conn_threads: Mutex::new(Vec::new()),
    });
    probe_all(&state);
    let accept_state = Arc::clone(&state);
    let accept_thread = std::thread::Builder::new()
        .name("hadacore-cluster-acceptor".to_string())
        .spawn(move || accept_loop(listener, &accept_state))
        .map_err(|e| anyhow!("spawn acceptor: {e}"))?;
    let health_state = Arc::clone(&state);
    let health_thread = std::thread::Builder::new()
        .name("hadacore-cluster-health".to_string())
        .spawn(move || health_loop(&health_state))
        .map_err(|e| anyhow!("spawn health prober: {e}"))?;
    Ok(ClusterHandle {
        addr,
        state,
        accept_thread: Some(accept_thread),
        health_thread: Some(health_thread),
    })
}

impl ClusterHandle {
    /// The proxy's bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Proxy counters.
    pub fn counters(&self) -> &ClusterCounters {
        &self.state.counters
    }

    /// Number of configured backends.
    pub fn backend_count(&self) -> usize {
        self.state.backends.len()
    }

    /// Point-in-time view of backend `i`.
    pub fn backend(&self, i: usize) -> BackendSnapshot {
        self.state.backends[i].snapshot()
    }

    /// Stop routing *new* requests to backend `i`; in-flight requests
    /// complete normally. Safe to call repeatedly.
    pub fn drain_backend(&self, i: usize) {
        self.state.backends[i].draining.store(true, Ordering::Release);
    }

    /// Re-admit backend `i` to routing (after a drain).
    pub fn undrain_backend(&self, i: usize) {
        self.state.backends[i].draining.store(false, Ordering::Release);
    }

    /// Point backend `i` at a new address (a restarted shard rarely
    /// comes back on the same ephemeral port) and probe it once; the
    /// slot rejoins routing as soon as it answers a ping — here, or on
    /// a later health tick.
    pub fn replace_backend(&self, i: usize, addr: &str) {
        let b = &self.state.backends[i];
        *b.addr.lock().unwrap() = addr.to_string();
        b.healthy.store(false, Ordering::Release);
        *b.client.lock().unwrap() = None;
        let ok = b
            .alive_client(self.state.cfg.max_frame_bytes)
            .map(|c| c.ping().is_ok())
            .unwrap_or(false);
        b.healthy.store(ok, Ordering::Release);
    }

    /// Route keys shard `i` has been handed since the last
    /// [`ClusterHandle::reset_route_keys`] — the homogeneity
    /// bookkeeping the cluster tests assert on.
    pub fn route_keys(&self, i: usize) -> Vec<RouteKey> {
        self.state.backends[i].keys.lock().unwrap().iter().copied().collect()
    }

    /// Clear every shard's route-key bookkeeping (e.g. between a
    /// failover phase and a homogeneity phase of a test).
    pub fn reset_route_keys(&self) {
        for b in &self.state.backends {
            b.keys.lock().unwrap().clear();
        }
    }

    /// Stop accepting, resolve relay bookkeeping, join all threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.state.shutdown.swap(true, Ordering::AcqRel) {
            return;
        }
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake {
                SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let woke = TcpStream::connect_timeout(&wake, Duration::from_secs(1)).is_ok();
        if let Some(h) = self.accept_thread.take() {
            if woke {
                let _ = h.join();
            }
        }
        let conns: Vec<JoinHandle<()>> =
            self.state.conn_threads.lock().unwrap().drain(..).collect();
        for h in conns {
            let _ = h.join();
        }
        if let Some(h) = self.health_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ClusterHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---------------------------------------------------------------------
// Self-healing supervisor.

/// Handle to a running [`supervise`] loop; [`SupervisorHandle::shutdown`]
/// (or drop) stops and joins it.
pub struct SupervisorHandle {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl SupervisorHandle {
    /// Stop the loop and join its thread.
    pub fn shutdown(mut self) {
        self.stop_join();
    }

    fn stop_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SupervisorHandle {
    fn drop(&mut self) {
        self.stop_join();
    }
}

/// Self-healing loop for *owned* backends: every `interval`, poll each
/// slot's liveness; a dead slot is respawned and handed back to routing
/// via [`ClusterHandle::replace_backend`] (counted on
/// `hadacore_cluster_restarts_total`). Liveness and respawning are
/// closures, so `hadacore cluster --spawn` (child processes,
/// `try_wait`) and in-process tests (serve handles behind a flag) drive
/// the same loop. A slot whose respawn fails (`None`) stays dead and is
/// retried next sweep; routing keeps failing over around it meanwhile.
pub fn supervise(
    handle: &Arc<ClusterHandle>,
    interval: Duration,
    mut alive: impl FnMut(usize) -> bool + Send + 'static,
    mut respawn: impl FnMut(usize) -> Option<String> + Send + 'static,
) -> anyhow::Result<SupervisorHandle> {
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    let handle = Arc::clone(handle);
    let thread = std::thread::Builder::new()
        .name("hadacore-cluster-supervisor".to_string())
        .spawn(move || {
            let n = handle.backend_count();
            while !stop_flag.load(Ordering::Acquire) {
                for i in 0..n {
                    if stop_flag.load(Ordering::Acquire) {
                        return;
                    }
                    if alive(i) {
                        continue;
                    }
                    if let Some(addr) = respawn(i) {
                        handle.counters().restarts.fetch_add(1, Ordering::Relaxed);
                        handle.replace_backend(i, &addr);
                    }
                }
                // poll-sized sleeps so shutdown isn't gated on a sweep
                let mut left = interval;
                while left > Duration::ZERO && !stop_flag.load(Ordering::Acquire) {
                    let step = left.min(Duration::from_millis(10));
                    std::thread::sleep(step);
                    left = left.saturating_sub(step);
                }
            }
        })
        .map_err(|e| anyhow!("spawn supervisor: {e}"))?;
    Ok(SupervisorHandle { stop, thread: Some(thread) })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: u32) -> RouteKey {
        RouteKey {
            n,
            dtype: DType::F32,
            epilogue: Epilogue::None,
            prologue: Prologue::None,
        }
    }

    #[test]
    fn rendezvous_is_deterministic_and_spreads() {
        let kh = key_hash(&key(1024));
        assert_eq!(rendezvous_score(kh, 0), rendezvous_score(kh, 0));
        assert_ne!(rendezvous_score(kh, 0), rendezvous_score(kh, 1));
        // different keys land on different winners often enough to
        // actually shard: over many sizes, a 3-way fleet must see every
        // backend win at least once
        let mut winners = HashSet::new();
        for n in (0..64u32).map(|i| 256 << (i % 8)).chain(1..64) {
            let kh = key_hash(&key(n));
            let best = (0..3).max_by_key(|&b| rendezvous_score(kh, b)).unwrap();
            winners.insert(best);
        }
        assert_eq!(winners.len(), 3, "all shards must own some keys");
    }

    #[test]
    fn route_key_includes_the_bucket_coordinates() {
        let mut req = WireRequest::from_f32(
            7,
            1024,
            &vec![0.0f32; 1024],
            crate::hadamard::KernelKind::HadaCore,
            DType::F32,
        );
        let a = RouteKey::of(&req);
        req.epilogue = Epilogue::QuantInt8 { group: 64 };
        let b = RouteKey::of(&req);
        assert_ne!(a, b, "epilogue must discriminate the route");
        req.prologue = Prologue::SignFlip { seed: 0x5EED };
        let c = RouteKey::of(&req);
        assert_ne!(b, c, "prologue must discriminate the route");
        // id and scale must NOT discriminate: same bucket, same shard
        req.id = 99;
        req.scale = Some(2.0);
        let d = RouteKey::of(&req);
        assert_eq!(c, d, "id/scale are not bucket coordinates");
    }
}
