//! Open-loop load generator for the TCP serving layer.
//!
//! Drives a configured QPS of transform requests through [`Client`]
//! connections — *open loop*: request send times follow the offered-rate
//! schedule, not the server's completions, so queueing delay shows up in
//! the measured latency instead of silently throttling the offered load
//! (the coordinated-omission trap closed-loop benches fall into). The
//! only concession is a per-connection outstanding-window bound
//! ([`LoadgenConfig::max_outstanding`]) so a stalled server bounds
//! memory, not the schedule.
//!
//! Traffic models are the [`crate::harness::workload`] mixes
//! ([`traffic_mix`](crate::harness::workload::traffic_mix)), so the
//! loadgen exercises exactly the request distributions the in-process
//! benches measure. Results aggregate into a [`LoadgenReport`] —
//! achieved QPS, latency percentiles, shed (`Busy`) counts, and the
//! tracked-thread allocation delta (the zero-alloc serving gate; see
//! [`crate::util::alloc`]) — and convert to [`BenchRecord`]s for the
//! `BENCH_PR7.json` perf trajectory.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::harness::workload::{ServingWorkload, WorkloadConfig};
use crate::util::alloc;
use crate::util::bench::{BenchRecord, Stats};
use crate::util::error::{self as anyhow, anyhow};
use crate::util::f16::DType;

use super::client::{Client, PendingReply, Reply};
use super::wire::WireRequest;

/// Load-generation configuration for one traffic mix.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address.
    pub addr: String,
    /// Mix label (reported and recorded; usually a
    /// [`crate::harness::workload::traffic_mix`] name).
    pub mix: String,
    /// The traffic model: sizes, row range, kernel, epilogue, seed.
    pub workload: WorkloadConfig,
    /// Offered load in requests/second across all connections
    /// (`0` = unpaced, send as fast as the window allows).
    pub qps: f64,
    /// Total requests to send.
    pub requests: usize,
    /// Client connections (requests round-robin across them).
    pub clients: usize,
    /// Wire dtype for payloads.
    pub dtype: DType,
    /// Per-connection outstanding-reply window (memory bound; large
    /// enough to never pace an honest server).
    pub max_outstanding: usize,
    /// Stamp a fresh span-trace id onto every `trace_every`-th request
    /// per connection (0 = never): deterministic trace coverage for the
    /// observability smoke paths, independent of the server-side
    /// `HADACORE_TRACE_SAMPLE` rate.
    pub trace_every: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            mix: "mixed".to_string(),
            workload: WorkloadConfig::default(),
            qps: 2000.0,
            requests: 2000,
            clients: 4,
            dtype: DType::F32,
            // stays under the server's default per-connection pipelining
            // cap (32) so an honest run never sheds on the window itself
            max_outstanding: 24,
            trace_every: 0,
        }
    }
}

/// Aggregated result of one loadgen run.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// Mix label.
    pub mix: String,
    /// Offered rate (0 = unpaced).
    pub offered_qps: f64,
    /// Completed (ok) requests per wall second.
    pub achieved_qps: f64,
    /// Requests sent.
    pub sent: u64,
    /// Successful responses.
    pub ok: u64,
    /// Retriable `Busy` sheds.
    pub busy: u64,
    /// Error replies.
    pub errors: u64,
    /// Replies lost to disconnects.
    pub disconnects: u64,
    /// Elements transformed (ok responses only).
    pub elems: u64,
    /// Wall time of the run.
    pub wall: Duration,
    /// Client-observed latencies of ok responses in µs, sorted.
    pub latencies_us: Vec<f64>,
    /// Heap-allocation calls observed on *tracked* (server-side) threads
    /// over this run's window. Meaningful only when `alloc_counting`;
    /// loadgen client threads are never tracked, so a self-hosted run
    /// measures exactly the serve path (see [`crate::util::alloc`]).
    pub alloc_allocs: u64,
    /// Bytes requested by those allocation calls.
    pub alloc_bytes: u64,
    /// Whether the counting allocator was installed (`count-alloc`
    /// feature); `false` means the alloc fields are vacuously zero.
    pub alloc_counting: bool,
}

impl LoadgenReport {
    /// Latency percentile in µs over ok responses.
    pub fn percentile_us(&self, p: f64) -> f64 {
        crate::util::bench::percentile(&self.latencies_us, p)
    }

    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<12} offered {:>7.0} qps  achieved {:>7.0} qps  ok {}  busy {}  err {}  \
             p50 {:.0}us  p90 {:.0}us  p99 {:.0}us",
            self.mix,
            self.offered_qps,
            self.achieved_qps,
            self.ok,
            self.busy,
            self.errors + self.disconnects,
            self.percentile_us(50.0),
            self.percentile_us(90.0),
            self.percentile_us(99.0),
        )
    }

    /// Convert to a perf-trajectory record (`hadacore-bench-v1` entry):
    /// the mix's shape envelope as `n`/`rows`, end-to-end element
    /// throughput, and QPS/latency/shed measurements as extras.
    pub fn to_record(&self, cfg: &LoadgenConfig) -> BenchRecord {
        let stats = Stats::from_sorted_us(
            &format!("loadgen:{}", self.mix),
            &self.latencies_us,
        );
        let melems =
            self.elems as f64 / self.wall.as_secs_f64().max(1e-9) / 1e6;
        BenchRecord::serving(
            "loadgen",
            cfg.workload.kernel.name(),
            cfg.workload.sizes.iter().copied().max().unwrap_or(1),
            cfg.workload.rows_max,
            cfg.dtype.name(),
            cfg.clients,
            stats,
            melems.max(f64::MIN_POSITIVE),
        )
        .with_extra("qps_offered", self.offered_qps)
        .with_extra("qps_achieved", self.achieved_qps)
        .with_extra("sent", self.sent as f64)
        .with_extra("ok", self.ok as f64)
        .with_extra("busy", self.busy as f64)
        .with_extra("errors", (self.errors + self.disconnects) as f64)
        .with_extra("p50_us", self.percentile_us(50.0))
        .with_extra("p90_us", self.percentile_us(90.0))
        .with_extra("p99_us", self.percentile_us(99.0))
        .with_extra("alloc_counting", f64::from(u8::from(self.alloc_counting)))
        .with_extra("allocs_steady", self.alloc_allocs as f64)
        .with_extra("alloc_per_req", self.allocs_per_request())
        .with_extra("alloc_bytes_per_req", self.alloc_bytes_per_request())
        // which SIMD dispatch table served the run (1 = scalar): the
        // perf trajectory must attribute throughput to the vector ISA
        .with_extra(
            "simd_lanes",
            crate::hadamard::simd::active().lanes() as f64,
        )
    }

    /// Tracked server-side allocation calls per ok response (the
    /// zero-alloc gate's headline number; 0.0 when nothing completed).
    pub fn allocs_per_request(&self) -> f64 {
        self.alloc_allocs as f64 / (self.ok as f64).max(1.0)
    }

    /// Tracked server-side allocated bytes per ok response.
    pub fn alloc_bytes_per_request(&self) -> f64 {
        self.alloc_bytes as f64 / (self.ok as f64).max(1.0)
    }
}

/// The open-loop send deadline of global request `index` at `qps`.
fn due_at(t0: Instant, index: usize, qps: f64) -> Instant {
    if qps <= 0.0 {
        return t0;
    }
    t0 + Duration::from_secs_f64(index as f64 / qps)
}

struct Partial {
    sent: u64,
    ok: u64,
    busy: u64,
    errors: u64,
    disconnects: u64,
    elems: u64,
    latencies_us: Vec<f64>,
}

/// Run one traffic mix against a server.
pub fn run(cfg: &LoadgenConfig) -> anyhow::Result<LoadgenReport> {
    if cfg.clients == 0 || cfg.requests == 0 {
        return Err(anyhow!("loadgen needs clients >= 1 and requests >= 1"));
    }
    // tracked-thread (server-side) allocation window for this run; the
    // caller decides what the delta means (a measured run is preceded by
    // a warmup run that populates the pool shelves and scratch buffers)
    let alloc0 = alloc::tracked();
    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel::<anyhow::Result<Partial>>();
    let mut threads = Vec::with_capacity(cfg.clients);
    for idx in 0..cfg.clients {
        let cfg = cfg.clone();
        let tx = tx.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("hadacore-loadgen-{idx}"))
                .spawn(move || {
                    let _ = tx.send(client_thread(&cfg, idx, t0));
                })
                .map_err(|e| anyhow!("spawn loadgen client: {e}"))?,
        );
    }
    drop(tx);
    let mut agg = Partial {
        sent: 0,
        ok: 0,
        busy: 0,
        errors: 0,
        disconnects: 0,
        elems: 0,
        latencies_us: Vec::new(),
    };
    let mut first_err = None;
    while let Ok(result) = rx.recv() {
        match result {
            Ok(p) => {
                agg.sent += p.sent;
                agg.ok += p.ok;
                agg.busy += p.busy;
                agg.errors += p.errors;
                agg.disconnects += p.disconnects;
                agg.elems += p.elems;
                agg.latencies_us.extend(p.latencies_us);
            }
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    for h in threads {
        let _ = h.join();
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall = t0.elapsed();
    let alloc_delta = alloc::tracked().since(alloc0);
    agg.latencies_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(LoadgenReport {
        mix: cfg.mix.clone(),
        offered_qps: cfg.qps,
        achieved_qps: agg.ok as f64 / wall.as_secs_f64().max(1e-9),
        sent: agg.sent,
        ok: agg.ok,
        busy: agg.busy,
        errors: agg.errors,
        disconnects: agg.disconnects,
        elems: agg.elems,
        wall,
        latencies_us: agg.latencies_us,
        alloc_allocs: alloc_delta.allocs,
        alloc_bytes: alloc_delta.bytes,
        alloc_counting: alloc::is_counting(),
    })
}

fn record_reply(p: &mut Partial, sent_at: Instant, reply: Reply) {
    match reply {
        Reply::Response(r) => {
            p.ok += 1;
            p.elems += r.rows as u64 * r.n as u64;
            p.latencies_us.push(sent_at.elapsed().as_micros() as f64);
        }
        Reply::Busy { .. } => p.busy += 1,
        Reply::Error { .. } => p.errors += 1,
        Reply::Disconnected => p.disconnects += 1,
        // Pong/Stats never answer a transform request
        _ => p.errors += 1,
    }
}

fn drain_ready(p: &mut Partial, outstanding: &mut Vec<(Instant, PendingReply)>) {
    let mut i = 0;
    while i < outstanding.len() {
        match outstanding[i].1.try_wait() {
            Some(reply) => {
                let (sent_at, _) = outstanding.remove(i);
                record_reply(p, sent_at, reply);
            }
            None => i += 1,
        }
    }
}

fn client_thread(
    cfg: &LoadgenConfig,
    idx: usize,
    t0: Instant,
) -> anyhow::Result<Partial> {
    let client = Client::connect(&cfg.addr)?;
    // distinct deterministic stream per connection
    let mut wl = ServingWorkload::new(WorkloadConfig {
        seed: cfg.workload.seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(idx as u64 + 1)),
        ..cfg.workload.clone()
    });
    let mut p = Partial {
        sent: 0,
        ok: 0,
        busy: 0,
        errors: 0,
        disconnects: 0,
        elems: 0,
        latencies_us: Vec::new(),
    };
    let share = cfg.requests / cfg.clients
        + usize::from(idx < cfg.requests % cfg.clients);
    let mut outstanding: Vec<(Instant, PendingReply)> = Vec::new();
    for i in 0..share {
        // pace to the open-loop schedule, harvesting replies while idle
        let due = due_at(t0, i * cfg.clients + idx, cfg.qps);
        loop {
            drain_ready(&mut p, &mut outstanding);
            let now = Instant::now();
            if now >= due {
                break;
            }
            std::thread::sleep((due - now).min(Duration::from_micros(200)));
        }
        let req = wl.next_request();
        let mut wire =
            WireRequest::from_f32(0, req.n, &req.data, req.kernel, cfg.dtype);
        wire.epilogue = req.epilogue;
        wire.scale = req.scale;
        wire.force_native = req.force_native;
        if cfg.trace_every > 0 && i % cfg.trace_every == 0 {
            wire.trace = crate::obs::trace::next_trace_id();
        }
        // paced runs charge latency from the *scheduled* send time, so a
        // send delayed by the outstanding window (or a slow submit) shows
        // up as latency instead of silently shifting the schedule — the
        // coordinated-omission correction; unpaced runs have no schedule
        // and use the actual send instant
        let basis = if cfg.qps > 0.0 { due } else { Instant::now() };
        match client.submit(wire) {
            Ok(pending) => {
                p.sent += 1;
                outstanding.push((basis, pending));
            }
            Err(_) => {
                // connection is gone; the failed attempt still counts as
                // sent (keeping ok+busy+errors+disconnects == sent), and
                // everything outstanding resolves as disconnected below —
                // the unsent remainder shows up as sent < requests
                p.sent += 1;
                p.errors += 1;
                break;
            }
        }
        // bound memory: block on the oldest reply past the window
        while outstanding.len() >= cfg.max_outstanding.max(1) {
            let (sent_at, pending) = outstanding.remove(0);
            record_reply(&mut p, sent_at, pending.wait());
        }
    }
    for (sent_at, pending) in outstanding {
        record_reply(&mut p, sent_at, pending.wait());
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_loop_schedule_is_rate_accurate() {
        let t0 = Instant::now();
        // 1000 qps: request k is due k ms after start
        assert_eq!(due_at(t0, 0, 1000.0), t0);
        assert_eq!(due_at(t0, 500, 1000.0) - t0, Duration::from_millis(500));
        // unpaced: everything due immediately
        assert_eq!(due_at(t0, 12345, 0.0), t0);
    }

    #[test]
    fn report_percentiles_and_record() {
        let report = LoadgenReport {
            mix: "mixed".to_string(),
            offered_qps: 100.0,
            achieved_qps: 95.0,
            sent: 100,
            ok: 95,
            busy: 5,
            errors: 0,
            disconnects: 0,
            elems: 95 * 1024,
            wall: Duration::from_secs(1),
            latencies_us: (1..=95).map(|i| i as f64 * 10.0).collect(),
            alloc_allocs: 0,
            alloc_bytes: 0,
            alloc_counting: false,
        };
        assert!((report.percentile_us(50.0) - 480.0).abs() < 1.0);
        let line = report.line();
        assert!(line.contains("busy 5"), "got: {line}");
        let cfg = LoadgenConfig {
            workload: WorkloadConfig { sizes: vec![256, 1024], ..Default::default() },
            ..Default::default()
        };
        let rec = report.to_record(&cfg);
        assert_eq!(rec.n, 1024, "shape envelope = largest size in the mix");
        assert!(rec.melems_per_s > 0.0);
        assert!(rec
            .extras
            .iter()
            .any(|(k, v)| k == "busy" && *v == 5.0));
        assert!(
            rec.extras
                .iter()
                .any(|(k, v)| k == "alloc_counting" && *v == 0.0),
            "records must carry the counting-active flag so a zero \
             allocs_steady is distinguishable from an unmeasured run"
        );
        assert!(rec.extras.iter().any(|(k, _)| k == "alloc_per_req"));
        // the dispatch provenance: lanes of whatever backend is active
        // in this process (1 when the scalar table is frozen in)
        let want_lanes = crate::hadamard::simd::active().lanes() as f64;
        assert!(
            rec.extras
                .iter()
                .any(|(k, v)| k == "simd_lanes" && *v == want_lanes),
            "records must attribute throughput to the active SIMD backend"
        );
    }
}
