//! The TCP front-end: acceptor, bounded connection handlers, admission
//! control, and out-of-order response streaming.
//!
//! ## Threading model
//!
//! One **acceptor** thread owns the [`TcpListener`]. Each admitted
//! connection gets a **reader** (the spawned handler thread itself) and a
//! **writer** thread; the pool is bounded by
//! [`ServeConfig::max_conns`] — connections beyond the bound receive a
//! retriable [`Frame::Busy`] and are closed, never queued invisibly.
//!
//! The reader decodes frames **directly into pooled buffers**
//! ([`decode_server_frame`] + the shared [`serve_pool`]) and submits
//! admitted requests to the shared [`Coordinator`] via
//! [`Coordinator::submit_to`], passing per-request clones of the
//! connection's [`ReplyRing`] sender. The writer drains the ring and
//! frames responses **in completion order** — requests pipelined by a
//! client come back possibly out of order, matched by id. Control frames
//! (`Busy`, `Error`, `Pong`, `Stats`) are written by the reader under the
//! same write-side mutex, so frames never interleave mid-frame.
//!
//! ## Zero-copy request path
//!
//! A payload touches exactly one buffer for its whole server-side life:
//! the reader widens wire bytes into a [`PooledBuf`](crate::util::pool)
//! sized for `rows * n`, the coordinator's batcher hands the exec engine
//! a scatter-gather region view of that same buffer (transform runs
//! in place), and the writer serialises the response by framing the
//! buffer's raw bytes with a [`ResponseFramer`] + vectored write — no
//! gather copy, no encode copy. The buffer returns to the pool when the
//! response drops, on *every* path (shed, error, teardown) via RAII.
//! In steady state (pool shelves warm, ring and scratch at capacity) a
//! request performs **zero heap allocations** end to end — asserted by
//! `tests/zero_alloc_pool.rs` under the `count-alloc` feature.
//!
//! ## Admission control
//!
//! A request is shed with a retriable `Busy` frame (the connection stays
//! open, nothing hangs) when any of three bounds is hit:
//!
//! 1. per-connection pipelining cap ([`ServeConfig::pipeline_depth`]),
//! 2. global in-flight cap ([`ServeConfig::max_inflight`]),
//! 3. coordinator queue depth ([`ServeConfig::max_queued_rows`] rows).
//!
//! Malformed bytes get an [`ErrorCode::Malformed`] error frame and the
//! connection closes (there is no way to resynchronise a corrupt length-
//! prefixed stream). Requests rejected by the router get a
//! [`ErrorCode::Rejected`] error frame and the connection stays open.
//!
//! ## Teardown
//!
//! [`ServeHandle::shutdown`] stops the acceptor, lets every reader
//! notice the flag (bounded by [`ServeConfig::poll_interval`]), and
//! joins writers — which first flush every in-flight response. Pair it
//! with [`Coordinator::drain`] for a full graceful stop: requests
//! admitted before shutdown complete with real responses.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{Coordinator, ReplyRing, ReplyTx, ResponseTx};
use crate::obs::trace::{self, Stage, TraceCtx};
use crate::quant::{Epilogue, QuantScales};
use crate::util::alloc::track_current_thread;
use crate::util::error::{self as anyhow, anyhow};
use crate::util::f16::DType;
use crate::util::pool::{scale_pool, serve_pool};

use super::wire::{
    decode_server_frame, write_frame_parts, ErrorCode, Frame, ResponseFramer,
    ServerFrame, WireError, WireStats, DEFAULT_MAX_FRAME_BYTES,
};

/// Serving-layer configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port — the bound
    /// address is on [`ServeHandle::addr`]).
    pub addr: String,
    /// Connection-handler pool bound; further connections get a `Busy`
    /// frame and are closed.
    pub max_conns: usize,
    /// Global in-flight request cap across all connections.
    pub max_inflight: usize,
    /// Per-connection pipelining cap (in-flight requests on one socket).
    pub pipeline_depth: usize,
    /// Shed new requests while the coordinator has more than this many
    /// rows queued (the queue-depth signal of the batcher).
    pub max_queued_rows: usize,
    /// Frame-size cap, enforced on inbound frames before any body
    /// allocation and at admission for outbound ones: a request whose
    /// *reply* (payload + epilogue scales) could not be encoded under
    /// the cap is rejected up front.
    pub max_frame_bytes: u32,
    /// Reader poll quantum: the latency bound on noticing shutdown while
    /// a connection is idle.
    pub poll_interval: Duration,
    /// Socket write timeout: a client that submits requests but stops
    /// reading replies fills the send buffer; without this bound its
    /// blocked `write` would pin the connection's writer (and the write
    /// mutex) forever and hang teardown. On expiry the connection is
    /// dead (a partial frame cannot resync) and is closed.
    pub write_timeout: Duration,
    /// Backoff hint carried by `Busy` frames.
    pub busy_retry_us: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 64,
            max_inflight: 256,
            pipeline_depth: 32,
            max_queued_rows: 8192,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            poll_interval: Duration::from_millis(50),
            write_timeout: Duration::from_secs(5),
            busy_retry_us: 1000,
        }
    }
}

/// Serve-layer counters (exposed through the `Stats` frame next to the
/// coordinator metrics) — registry-backed handles, so the same atomics
/// render in the `/metrics` exposition under `hadacore_*` names.
#[derive(Debug)]
pub struct ServeCounters {
    /// Connections admitted to the handler pool.
    pub conns_accepted: Arc<AtomicU64>,
    /// Connections shed at the pool bound.
    pub conns_rejected: Arc<AtomicU64>,
    /// Currently open connections.
    pub conns_active: Arc<AtomicU64>,
    /// Requests currently in flight (admitted, response not yet written).
    pub inflight: Arc<AtomicU64>,
    /// Requests shed with a `Busy` frame.
    pub busy_shed: Arc<AtomicU64>,
    /// Malformed frames / protocol violations observed.
    pub protocol_errors: Arc<AtomicU64>,
    /// Requests forwarded to the coordinator.
    pub requests: Arc<AtomicU64>,
}

impl ServeCounters {
    fn new() -> ServeCounters {
        let r = crate::obs::registry();
        ServeCounters {
            conns_accepted: r.counter(
                "hadacore_conns_accepted_total",
                "connections admitted to the handler pool",
            ),
            conns_rejected: r.counter(
                "hadacore_conns_rejected_total",
                "connections shed at the pool bound",
            ),
            conns_active: r.gauge("hadacore_conns_active", "currently open connections"),
            inflight: r.gauge(
                "hadacore_inflight",
                "admitted requests whose response is not yet written",
            ),
            busy_shed: r.counter(
                "hadacore_busy_shed_total",
                "requests shed with a Busy frame",
            ),
            protocol_errors: r.counter(
                "hadacore_protocol_errors_total",
                "malformed frames and protocol violations",
            ),
            requests: r.counter(
                "hadacore_serve_requests_total",
                "requests forwarded to the coordinator",
            ),
        }
    }
}

impl Default for ServeCounters {
    fn default() -> Self {
        ServeCounters::new()
    }
}

struct ServeState {
    coord: Arc<Coordinator>,
    cfg: ServeConfig,
    shutdown: AtomicBool,
    counters: ServeCounters,
    conn_threads: Mutex<Vec<JoinHandle<()>>>,
}

/// Handle to a running server; dropping it shuts the server down.
pub struct ServeHandle {
    addr: SocketAddr,
    state: Arc<ServeState>,
    accept_thread: Option<JoinHandle<()>>,
}

/// Bind and start serving `coord` on `cfg.addr`.
pub fn serve(coord: Arc<Coordinator>, cfg: ServeConfig) -> anyhow::Result<ServeHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| anyhow!("bind {}: {e}", cfg.addr))?;
    let addr = listener
        .local_addr()
        .map_err(|e| anyhow!("local_addr: {e}"))?;
    let state = Arc::new(ServeState {
        coord,
        cfg,
        shutdown: AtomicBool::new(false),
        counters: ServeCounters::default(),
        conn_threads: Mutex::new(Vec::new()),
    });
    let accept_state = Arc::clone(&state);
    let accept_thread = std::thread::Builder::new()
        .name("hadacore-acceptor".to_string())
        .spawn(move || accept_loop(listener, &accept_state))
        .map_err(|e| anyhow!("spawn acceptor: {e}"))?;
    Ok(ServeHandle { addr, state, accept_thread: Some(accept_thread) })
}

impl ServeHandle {
    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve-layer counters.
    pub fn counters(&self) -> &ServeCounters {
        &self.state.counters
    }

    /// Stop accepting, let in-flight responses flush, join all threads.
    /// Does **not** drain the shared coordinator — call
    /// [`Coordinator::drain`] after this for a full graceful stop.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.state.shutdown.swap(true, Ordering::AcqRel) {
            return;
        }
        // wake the blocking accept() with a throwaway connection. A
        // wildcard bind (0.0.0.0 / ::) is not connectable on every
        // platform, so aim at loopback on the bound port; bound by a
        // timeout so shutdown never inherits a hang from the network.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake {
                SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let woke =
            TcpStream::connect_timeout(&wake, Duration::from_secs(1)).is_ok();
        if let Some(h) = self.accept_thread.take() {
            if woke {
                let _ = h.join();
            }
            // else: the acceptor could not be woken (unreachable bind
            // address). Leave it parked instead of hanging shutdown —
            // the flag is set, so if a connection ever does arrive the
            // loop exits without serving it, and process exit reclaims
            // the thread either way.
        }
        let conns: Vec<JoinHandle<()>> =
            self.state.conn_threads.lock().unwrap().drain(..).collect();
        for h in conns {
            let _ = h.join();
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, state: &Arc<ServeState>) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) => {
                if state.shutdown.load(Ordering::Acquire) {
                    return;
                }
                // e.g. EMFILE under overload: back off instead of
                // busy-spinning the core the handlers need to free fds
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if state.shutdown.load(Ordering::Acquire) {
            return; // the wake-up connection (or a late arrival)
        }
        // reap finished handlers so the handle list stays bounded by the
        // number of *live* connections, not the connection history
        {
            let mut threads = state.conn_threads.lock().unwrap();
            let mut live = Vec::with_capacity(threads.len());
            for h in threads.drain(..) {
                if h.is_finished() {
                    let _ = h.join();
                } else {
                    live.push(h);
                }
            }
            *threads = live;
        }
        if state.counters.conns_active.load(Ordering::Acquire) >= state.cfg.max_conns as u64 {
            state.counters.conns_rejected.fetch_add(1, Ordering::Relaxed);
            let mut s = stream;
            let busy =
                Frame::Busy { id: 0, retry_after_us: state.cfg.busy_retry_us };
            let _ = s.write_all(&busy.encode());
            let _ = s.shutdown(Shutdown::Both);
            continue;
        }
        state.counters.conns_active.fetch_add(1, Ordering::AcqRel);
        state.counters.conns_accepted.fetch_add(1, Ordering::Relaxed);
        let conn_state = Arc::clone(state);
        match std::thread::Builder::new()
            .name("hadacore-conn".to_string())
            .spawn(move || handle_conn(&conn_state, stream))
        {
            Ok(h) => state.conn_threads.lock().unwrap().push(h),
            Err(_) => {
                state.counters.conns_active.fetch_sub(1, Ordering::AcqRel);
            }
        }
    }
}

/// Write one frame under the connection's write mutex (reader-side
/// control frames and writer-side responses share it, so frames never
/// interleave).
fn send_locked(half: &Mutex<TcpStream>, frame: &Frame) -> std::io::Result<()> {
    let bytes = frame.encode();
    let mut s = half.lock().unwrap();
    s.write_all(&bytes)
}

fn handle_conn(state: &Arc<ServeState>, stream: TcpStream) {
    // connection readers widen payloads into pooled buffers: count their
    // allocations when the count-alloc gate is measuring (no-op otherwise)
    track_current_thread(true);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(state.cfg.poll_interval));
    let _ = stream.set_write_timeout(Some(state.cfg.write_timeout));
    let result = stream.try_clone();
    match result {
        Ok(write_stream) => {
            let write_half = Arc::new(Mutex::new(write_stream));
            conn_loop(state, stream, &write_half);
        }
        Err(_) => drop(stream),
    }
    state.counters.conns_active.fetch_sub(1, Ordering::AcqRel);
}

/// Per-request bookkeeping the writer needs to encode the response in
/// the dtype the request arrived with (plus the trace context, so the
/// writer can record the framed/written spans).
type InflightMeta = Arc<Mutex<HashMap<u64, (DType, u32, TraceCtx)>>>;

fn conn_loop(
    state: &Arc<ServeState>,
    mut reader: TcpStream,
    write_half: &Arc<Mutex<TcpStream>>,
) {
    // a ReplyRing instead of std mpsc: mpsc allocates a node per message,
    // which would be one heap allocation per response in steady state.
    // Depth 2x the pipeline cap so admission never sends into a full ring.
    let (ring, tx) = ReplyRing::with_depth(state.cfg.pipeline_depth * 2);
    let conn_inflight = Arc::new(AtomicUsize::new(0));
    let meta: InflightMeta = Arc::new(Mutex::new(HashMap::new()));

    let writer = {
        let state = Arc::clone(state);
        let write_half = Arc::clone(write_half);
        let conn_inflight = Arc::clone(&conn_inflight);
        let meta = Arc::clone(&meta);
        std::thread::Builder::new()
            .name("hadacore-conn-writer".to_string())
            .spawn(move || writer_loop(&state, &write_half, &ring, &conn_inflight, &meta))
    };
    let writer = match writer {
        Ok(w) => w,
        Err(_) => return,
    };

    // Incremental framing: accumulate bytes and peel complete frames off
    // the front. The read timeout (the shutdown-poll quantum) is only
    // ever hit by `read`, which consumes nothing on timeout — a frame
    // that straddles a network stall stays intact in `buf` instead of
    // being torn mid-read (which read_exact-style framing would do).
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    'conn: loop {
        loop {
            match decode_server_frame(&buf, state.cfg.max_frame_bytes, serve_pool()) {
                Ok(Some((frame, used))) => {
                    buf.drain(..used);
                    if !handle_frame(state, write_half, &tx, &conn_inflight, &meta, frame)
                    {
                        break 'conn;
                    }
                }
                Ok(None) => break, // need more bytes
                Err(msg) => {
                    state.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = send_locked(
                        write_half,
                        &Frame::Error(WireError {
                            id: 0,
                            code: ErrorCode::Malformed,
                            msg,
                        }),
                    );
                    break 'conn; // a corrupt length-prefixed stream cannot resync
                }
            }
        }
        // exit check sits between "answer everything buffered" and
        // "read more": a client that keeps streaming frames cannot pin
        // this handler past shutdown (frames already received were
        // answered above — with Draining errors once the flag is up)
        if state.shutdown.load(Ordering::Acquire) {
            break;
        }
        match reader.read(&mut chunk) {
            Ok(0) => break, // EOF: client is done
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {} // poll quantum: re-check shutdown above
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break, // reset / hard error
        }
    }
    // dropping our sender lets the writer exit once the coordinator has
    // delivered (and the writer has flushed) every in-flight response
    drop(tx);
    let _ = writer.join();
    let _ = reader.shutdown(Shutdown::Both);
}

/// React to one decoded frame; returns false to close the connection.
///
/// Every reader-side write propagates its success: a failed (or timed
/// out) control-frame write may have torn a partial frame into the
/// stream, so the connection must close — and closing also stops a
/// non-reading client from costing one write-timeout per buffered
/// frame.
fn handle_frame(
    state: &Arc<ServeState>,
    write_half: &Arc<Mutex<TcpStream>>,
    tx: &ReplyTx,
    conn_inflight: &Arc<AtomicUsize>,
    meta: &InflightMeta,
    frame: ServerFrame,
) -> bool {
    match frame {
        ServerFrame::Control(Frame::Ping { id }) => {
            send_locked(write_half, &Frame::Pong { id }).is_ok()
        }
        ServerFrame::Control(Frame::StatsRequest { id }) => {
            let stats = build_stats(state, id);
            send_locked(write_half, &Frame::Stats(stats)).is_ok()
        }
        ServerFrame::Control(Frame::StatsTextRequest { id }) => {
            let text = crate::obs::registry().render();
            send_locked(write_half, &Frame::StatsText { id, text }).is_ok()
        }
        ServerFrame::Control(Frame::TraceRequest { id, trace: want }) => {
            let events = trace::drain_trace(want);
            send_locked(write_half, &Frame::TraceDump { id, events }).is_ok()
        }
        ServerFrame::Request(pr) => {
            let id = pr.id;
            // adopt the wire's trace id (proxy / tracing client) or make
            // the sampling decision here, at conn-reader admission; with
            // sampling off (the default) this is one branch and no event
            let trace_ctx = if pr.trace != 0 {
                TraceCtx(pr.trace)
            } else {
                trace::sample()
            };
            trace::event(trace_ctx, Stage::Decode, pr.rows);
            if state.shutdown.load(Ordering::Acquire) || state.coord.is_draining() {
                return send_locked(
                    write_half,
                    &Frame::Error(WireError {
                        id,
                        code: ErrorCode::Draining,
                        msg: "server is draining".to_string(),
                    }),
                )
                .is_ok();
            }
            // admission control: shed with a retriable Busy instead of
            // queueing without bound (or hanging the connection)
            let shed = conn_inflight.load(Ordering::Acquire)
                >= state.cfg.pipeline_depth
                || state.counters.inflight.load(Ordering::Acquire)
                    >= state.cfg.max_inflight as u64
                || state.coord.queued_rows() > state.cfg.max_queued_rows;
            if shed {
                state.counters.busy_shed.fetch_add(1, Ordering::Relaxed);
                return send_locked(
                    write_half,
                    &Frame::Busy { id, retry_after_us: state.cfg.busy_retry_us },
                )
                .is_ok();
            }
            // the response echoes the payload and adds epilogue scales:
            // reject a request whose *reply* could not be encoded under
            // the frame cap (the client's decoder would kill the
            // connection over a perfectly admitted request otherwise).
            // The payload size is recomputed from the wire shape — the
            // raw bytes were already widened into the pooled buffer.
            let elems = pr.rows as u64 * pr.n as u64;
            let scale_bytes = match pr.epilogue {
                Epilogue::QuantInt8 { group } => 4 * (elems / group.max(1) as u64) + 8,
                _ => 8,
            };
            let payload_bytes = elems * pr.dtype.size_bytes() as u64;
            let resp_bytes = 96 + payload_bytes + scale_bytes;
            if resp_bytes > state.cfg.max_frame_bytes as u64 {
                return send_locked(
                    write_half,
                    &Frame::Error(WireError {
                        id,
                        code: ErrorCode::Rejected,
                        msg: format!(
                            "response would need ~{resp_bytes} bytes, over the \
                             frame cap {}",
                            state.cfg.max_frame_bytes
                        ),
                    }),
                )
                .is_ok();
            }
            match meta.lock().unwrap().entry(id) {
                Entry::Occupied(_) => {
                    // the frame itself decoded fine, so this is a
                    // rejected request, not a corrupt stream — Malformed
                    // would (per the wire contract) imply the connection
                    // is about to close, which it is not
                    state.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    return send_locked(
                        write_half,
                        &Frame::Error(WireError {
                            id,
                            code: ErrorCode::Rejected,
                            msg: format!("duplicate in-flight request id {id}"),
                        }),
                    )
                    .is_ok();
                }
                Entry::Vacant(v) => {
                    v.insert((pr.dtype, pr.n, trace_ctx));
                }
            }
            // infallible: decode already enforced the strict shape check,
            // and the pooled buffer moves straight into the request
            let mut req = pr.into_transform();
            req.trace = trace_ctx;
            trace::event(trace_ctx, Stage::Admitted, req.rows as u32);
            conn_inflight.fetch_add(1, Ordering::AcqRel);
            state.counters.inflight.fetch_add(1, Ordering::AcqRel);
            match state.coord.submit_to(req, ResponseTx::Ring(tx.clone())) {
                Ok(()) => {
                    state.counters.requests.fetch_add(1, Ordering::Relaxed);
                    true
                }
                Err(e) => {
                    conn_inflight.fetch_sub(1, Ordering::AcqRel);
                    state.counters.inflight.fetch_sub(1, Ordering::AcqRel);
                    meta.lock().unwrap().remove(&id);
                    let code = if state.coord.is_draining() {
                        ErrorCode::Draining
                    } else {
                        ErrorCode::Rejected
                    };
                    send_locked(write_half, &Frame::Error(WireError { id, code, msg: e.0 }))
                        .is_ok()
                }
            }
        }
        // server-to-client frames arriving here are a protocol violation
        ServerFrame::Control(other) => {
            state.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
            let _ = send_locked(
                write_half,
                &Frame::Error(WireError {
                    id: other.id(),
                    code: ErrorCode::Malformed,
                    msg: "unexpected frame type from client".to_string(),
                }),
            );
            false
        }
    }
}

fn writer_loop(
    state: &Arc<ServeState>,
    write_half: &Arc<Mutex<TcpStream>>,
    ring: &ReplyRing,
    conn_inflight: &Arc<AtomicUsize>,
    meta: &InflightMeta,
) {
    // writers frame pooled response buffers: count their allocations when
    // the count-alloc gate is measuring (no-op otherwise)
    track_current_thread(true);
    // the connection-owned framing scratch: header bytes (and, for 16-bit
    // dtypes, the narrowing buffer) are built here and retained across
    // responses, so steady-state framing allocates nothing
    let mut framer = ResponseFramer::new();
    // after a write failure the client is gone: keep draining the ring
    // (the coordinator still owns sender clones and the counters must
    // come back down) but stop encoding
    let mut dead = false;
    while let Some((id, result)) = ring.recv() {
        let entry = meta.lock().unwrap().remove(&id);
        match result {
            Ok(mut resp) => {
                if !dead {
                    if let Some((dtype, n, trace_ctx)) = entry {
                        // zero-copy response: the header is framed next
                        // to a raw byte view of the transformed request
                        // buffer and both hit the socket in one vectored
                        // write — the payload is never re-encoded.
                        // `resp` (and its pooled buffer) drops right
                        // after, returning the buffer to the pool.
                        let ok = {
                            let (header, payload) = framer.frame(&resp, n, dtype);
                            trace::event(
                                trace_ctx,
                                Stage::Framed,
                                payload.len().min(u32::MAX as usize) as u32,
                            );
                            let mut s = write_half.lock().unwrap();
                            write_frame_parts(&mut *s, header, payload).is_ok()
                        };
                        if ok {
                            trace::event(trace_ctx, Stage::Written, 0);
                        }
                        if !ok {
                            // timeout or reset: a partially written
                            // frame cannot resync, so the connection is
                            // done — close it to unblock the (possibly
                            // stalled) peer-facing reader
                            dead = true;
                            let _ = write_half.lock().unwrap().shutdown(Shutdown::Both);
                        }
                    }
                }
                // the grouped-INT8 scale vector's last reader was the
                // framer (it copies the scales into the retained header
                // scratch): recycle it on every path — written, dead
                // connection, or missing meta — so steady INT8 traffic
                // allocates no scales (the payload buffer still returns
                // via PooledBuf's own Drop)
                if let QuantScales::PerGroup(v) =
                    std::mem::replace(&mut resp.scales, QuantScales::None)
                {
                    scale_pool().put(v);
                }
            }
            Err(e) => {
                if !dead && entry.is_some() {
                    let ok = send_locked(
                        write_half,
                        &Frame::Error(WireError {
                            id,
                            code: ErrorCode::ExecFailed,
                            msg: e.to_string(),
                        }),
                    )
                    .is_ok();
                    if !ok {
                        dead = true;
                        let _ = write_half.lock().unwrap().shutdown(Shutdown::Both);
                    }
                }
            }
        }
        conn_inflight.fetch_sub(1, Ordering::AcqRel);
        state.counters.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Assemble the `Stats` frame: coordinator snapshot + histogram
/// percentile reconstructions + serve-layer counters, with the full text
/// report a remote operator would otherwise need shell access for.
fn build_stats(state: &Arc<ServeState>, id: u64) -> WireStats {
    let m = state.coord.metrics();
    let s = m.snapshot();
    let c = &state.counters;
    let counters: Vec<(String, u64)> = [
        ("submitted", s.submitted),
        ("completed", s.completed),
        ("rejected", s.rejected),
        ("failed", s.failed),
        ("batches", s.batches),
        ("native_batches", s.native_batches),
        ("pjrt_batches", s.pjrt_batches),
        ("rows", s.rows),
        ("padded_rows", s.padded_rows),
        ("queue_p50_us", s.queue_p50_us),
        ("queue_p90_us", s.queue_p90_us),
        ("queue_p99_us", s.queue_p99_us),
        ("exec_p50_us", s.exec_p50_us),
        ("exec_p90_us", s.exec_p90_us),
        ("exec_p99_us", s.exec_p99_us),
        ("e2e_p50_us", s.e2e_p50_us),
        ("e2e_p90_us", s.e2e_p90_us),
        ("e2e_p95_us", s.e2e_p95_us),
        ("e2e_p99_us", s.e2e_p99_us),
        ("e2e_mean_us", s.e2e_mean_us as u64),
        ("conns_accepted", c.conns_accepted.load(Ordering::Relaxed)),
        ("conns_rejected", c.conns_rejected.load(Ordering::Relaxed)),
        ("conns_active", c.conns_active.load(Ordering::Relaxed) as u64),
        ("inflight", c.inflight.load(Ordering::Relaxed) as u64),
        ("busy_shed", c.busy_shed.load(Ordering::Relaxed)),
        ("protocol_errors", c.protocol_errors.load(Ordering::Relaxed)),
        ("requests", c.requests.load(Ordering::Relaxed)),
    ]
    .into_iter()
    .map(|(k, v)| (k.to_string(), v))
    .collect();
    let report = format!(
        "{}\n{}\n{}\n{}\nserve:    {} conns ({} active, {} shed), {} busy, {} protocol errors",
        s.report(),
        m.queue.report("queue"),
        m.exec.report("exec"),
        m.e2e.report("e2e"),
        c.conns_accepted.load(Ordering::Relaxed),
        c.conns_active.load(Ordering::Relaxed),
        c.conns_rejected.load(Ordering::Relaxed),
        c.busy_shed.load(Ordering::Relaxed),
        c.protocol_errors.load(Ordering::Relaxed),
    );
    WireStats { id, counters, report }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_bounded() {
        let cfg = ServeConfig::default();
        assert!(cfg.max_conns > 0);
        assert!(cfg.max_inflight >= cfg.pipeline_depth);
        assert!(cfg.max_frame_bytes >= 1 << 20);
        assert!(cfg.addr.ends_with(":0"), "default binds an ephemeral port");
    }
}
