//! The length-prefixed binary wire protocol of the TCP serving layer.
//!
//! Every frame is `len: u32` (little-endian, counting the bytes *after*
//! the length field) followed by `len` body bytes. The body starts with a
//! one-byte protocol version and a one-byte frame tag; the rest is
//! tag-specific. All integers are little-endian; floats travel as their
//! IEEE bit patterns, so an f32 payload round-trips **bit-exactly** — the
//! foundation of the serving layer's bit-identity guarantee against
//! direct [`Coordinator::submit`](crate::coordinator::Coordinator).
//!
//! | tag | frame | body after `(version, tag)` |
//! |---|---|---|
//! | 1 | `Request` | `id u64, n u32, rows u32, kernel u8, dtype u8, flags u8, epilogue u8, group u32, scale f32, [seed u64,] payload` |
//! | 2 | `Response` | `id u64, n u32, rows u32, dtype u8, backend u8, batch_rows u32, queue_us u64, exec_us u64, scales, payload` |
//! | 3 | `Error` | `id u64, code u8, msg_len u16, msg` |
//! | 4 | `Busy` | `id u64, retry_after_us u32` |
//! | 5 | `Ping` | `id u64` |
//! | 6 | `Pong` | `id u64` |
//! | 7 | `StatsRequest` | `id u64` |
//! | 8 | `Stats` | `id u64, n u32, n x {key_len u8, key, value u64}, report_len u32, report` |
//! | 9 | `StatsTextRequest` | `id u64` |
//! | 10 | `StatsText` | `id u64, text_len u32, text` |
//! | 11 | `TraceRequest` | `id u64, trace u64` |
//! | 12 | `TraceDump` | `id u64, n u32, n x {trace u64, stage u8, arg u32, t_us u64}` |
//!
//! Request `flags`: bit 0 = custom scale present (the `scale` field is
//! its bits; otherwise the field must be zero), bit 1 = force the native
//! backend, bit 2 = sign-flip prologue present (a `seed u64` field
//! follows `scale`; without the flag the field is absent, keeping
//! plain frames byte-identical to their pre-prologue encoding), bit 3 =
//! span-trace id present (a nonzero `trace u64` field follows the seed —
//! or `scale` when no seed — propagating the sampling decision across
//! processes, same backward-compatible trick as the seed); all
//! other bits must be zero. `epilogue`: 0 none, 1 FP8 e4m3,
//! 2 FP8 e5m2, 3 grouped INT8 (`group` must be nonzero exactly for
//! INT8). Response `scales`: `tag u8` = 0 none | 1 per-tensor (`f32`)
//! | 2 per-group (`count u32, count x f32`). Payloads are `rows * n`
//! elements in the frame's dtype (float32 = 4 bytes/elem, float16 /
//! bfloat16 = 2, converted with the crate's round-to-nearest-even
//! [`crate::util::f16`] codecs).
//!
//! Decoding is strict by design: an unknown version/tag/enum value, a
//! payload whose length disagrees with `rows * n * elem_size`, trailing
//! bytes after a parsed body, or a frame longer than the configured cap
//! all yield a descriptive [`Err`] — never a panic, and (because the
//! length prefix is validated before any allocation) never an oversized
//! allocation. Incomplete input is reported as "need more bytes", which
//! the server answers by reading on and a buffer-based caller treats as
//! truncation. `rust/tests/wire_protocol.rs` drives round-trip,
//! truncation, and garbage property tests over this module.

use crate::coordinator::{TransformRequest, TransformResponse};
use crate::hadamard::{KernelKind, Prologue};
use crate::obs::{SpanEvent, Stage, TraceCtx};
use crate::quant::{Epilogue, Fp8Format, QuantScales};
use crate::util::f16::{DType, Element, BF16, F16};
use crate::util::pool::{BufferPool, PooledBuf};

/// Protocol version carried by every frame.
pub const WIRE_VERSION: u8 = 1;

/// Default frame-size cap (64 MiB): comfortably above the largest legal
/// payload (`max_request_rows * MAX_HADAMARD_SIZE` would exceed it, but
/// serving-realistic batches are far smaller) while bounding what a
/// malformed length prefix can make the decoder allocate.
pub const DEFAULT_MAX_FRAME_BYTES: u32 = 1 << 26;

/// Hard cap on `Stats` counter entries (a frame claiming more is
/// malformed).
pub const MAX_STATS_COUNTERS: u32 = 4096;

/// Hard cap on `TraceDump` events (a frame claiming more is malformed).
/// Generous: a fleet drains at most `threads x RING_CAPACITY` events,
/// far below this for any realistic thread count.
pub const MAX_TRACE_EVENTS: u32 = 1 << 20;

/// Machine-readable error classes carried by [`Frame::Error`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The frame could not be decoded; the connection closes after this.
    Malformed,
    /// The coordinator's router rejected the request (not retriable as
    /// sent — the request itself is invalid).
    Rejected,
    /// The request was admitted but execution failed.
    ExecFailed,
    /// The server is draining; retriable against a fresh server.
    Draining,
}

impl ErrorCode {
    fn tag(self) -> u8 {
        match self {
            ErrorCode::Malformed => 1,
            ErrorCode::Rejected => 2,
            ErrorCode::ExecFailed => 3,
            ErrorCode::Draining => 4,
        }
    }

    fn from_tag(t: u8) -> Result<ErrorCode, String> {
        match t {
            1 => Ok(ErrorCode::Malformed),
            2 => Ok(ErrorCode::Rejected),
            3 => Ok(ErrorCode::ExecFailed),
            4 => Ok(ErrorCode::Draining),
            _ => Err(format!("unknown error code {t}")),
        }
    }
}

/// A transform request as it travels on the wire. `payload` holds
/// `rows * n` elements encoded in `dtype`.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    /// Client-assigned id, echoed by every reply frame.
    pub id: u64,
    /// Hadamard size (row length).
    pub n: u32,
    /// Row count (`payload.len() == rows * n * dtype.size_bytes()`).
    pub rows: u32,
    /// Kernel implementation to run.
    pub kernel: KernelKind,
    /// Payload element encoding.
    pub dtype: DType,
    /// Output scaling (`None` = orthonormal `1/sqrt(n)`).
    pub scale: Option<f32>,
    /// Force the native backend.
    pub force_native: bool,
    /// Fused sign-flip rotation prologue (seeded ±1 diagonal applied
    /// before the transform).
    pub prologue: Prologue,
    /// Fused rotate→quantize epilogue.
    pub epilogue: Epilogue,
    /// Span-trace id (0 = unsampled; nonzero values travel under
    /// `FLAG_HAS_TRACE` so plain frames keep the v1 layout).
    pub trace: u64,
    /// Row-major payload bytes in `dtype`.
    pub payload: Vec<u8>,
}

/// A transform response as it travels on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireResponse {
    /// Echoed request id.
    pub id: u64,
    /// Hadamard size.
    pub n: u32,
    /// Rows in the payload.
    pub rows: u32,
    /// Payload element encoding (echoes the request's dtype).
    pub dtype: DType,
    /// True when the batch executed on the PJRT backend.
    pub pjrt: bool,
    /// Rows in the executed batch (including padding).
    pub batch_rows: u32,
    /// Queue-wait time of this request.
    pub queue_us: u64,
    /// Kernel execution time of the batch.
    pub exec_us: u64,
    /// Epilogue scales ([`QuantScales::None`] for plain requests).
    pub scales: QuantScales,
    /// Transformed rows, encoded in `dtype`.
    pub payload: Vec<u8>,
}

/// An error reply (also used standalone for protocol errors).
#[derive(Clone, Debug, PartialEq)]
pub struct WireError {
    /// The offending request id (0 when no frame could be attributed).
    pub id: u64,
    /// Error class.
    pub code: ErrorCode,
    /// Human-readable detail.
    pub msg: String,
}

/// A server metrics snapshot: named counters plus the text report.
#[derive(Clone, Debug, PartialEq)]
pub struct WireStats {
    /// Echoed request id.
    pub id: u64,
    /// Named counter values (coordinator metrics + serve-layer counters,
    /// percentiles in µs).
    pub counters: Vec<(String, u64)>,
    /// Multi-line human-readable report (the same text an in-process
    /// caller gets from `MetricsSnapshot::report` + histogram reports).
    pub report: String,
}

/// One decoded protocol frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client → server transform request.
    Request(WireRequest),
    /// Server → client transform response (possibly out of order).
    Response(WireResponse),
    /// Server → client error reply.
    Error(WireError),
    /// Server → client load-shed reply: the request was *not* admitted
    /// and may be retried after the hinted backoff.
    Busy {
        /// Echoed request id.
        id: u64,
        /// Suggested client backoff before retrying.
        retry_after_us: u32,
    },
    /// Liveness probe.
    Ping {
        /// Echo id.
        id: u64,
    },
    /// Liveness reply.
    Pong {
        /// Echoed id.
        id: u64,
    },
    /// Client → server metrics request.
    StatsRequest {
        /// Echo id.
        id: u64,
    },
    /// Server → client metrics snapshot.
    Stats(WireStats),
    /// Client → server request for the Prometheus-style text exposition
    /// of the process-wide [`crate::obs::registry`].
    StatsTextRequest {
        /// Echo id.
        id: u64,
    },
    /// Server → client registry exposition.
    StatsText {
        /// Echoed request id.
        id: u64,
        /// The rendered exposition (`# HELP` / `# TYPE` / samples).
        text: String,
    },
    /// Client → server request to drain the flight recorder.
    TraceRequest {
        /// Echo id.
        id: u64,
        /// Trace id to filter to (0 = every recorded event).
        trace: u64,
    },
    /// Server → client flight-recorder drain (the cluster proxy merges
    /// its own events with its backends' before replying).
    TraceDump {
        /// Echoed request id.
        id: u64,
        /// Recorded span events, timestamp-sorted per process.
        events: Vec<SpanEvent>,
    },
}

// ---------------------------------------------------------------------
// Element payload codecs.

/// Encode f32 values into `dtype` wire bytes (f32 is bit-exact; 16-bit
/// dtypes narrow with round-to-nearest-even).
pub fn encode_elems(data: &[f32], dtype: DType) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * dtype.size_bytes());
    match dtype {
        DType::F32 => {
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        DType::F16 => {
            for v in data {
                out.extend_from_slice(&F16::from_f32(*v).0.to_le_bytes());
            }
        }
        DType::BF16 => {
            for v in data {
                out.extend_from_slice(&BF16::from_f32(*v).0.to_le_bytes());
            }
        }
    }
    out
}

/// Decode `dtype` wire bytes into f32 values (widening is exact for all
/// three dtypes).
pub fn decode_elems(bytes: &[u8], dtype: DType) -> Result<Vec<f32>, String> {
    let esize = dtype.size_bytes();
    if bytes.len() % esize != 0 {
        return Err(format!(
            "payload length {} is not a multiple of element size {esize}",
            bytes.len()
        ));
    }
    let mut out = Vec::with_capacity(bytes.len() / esize);
    widen_into(bytes, dtype, &mut out);
    Ok(out)
}

/// Widen `dtype` wire bytes into `out` (caller guarantees `bytes.len()`
/// is an element-size multiple and `out` has the capacity — the pooled
/// decode path relies on this appending nothing beyond capacity, i.e.
/// never allocating).
fn widen_into(bytes: &[u8], dtype: DType, out: &mut Vec<f32>) {
    match dtype {
        DType::F32 => {
            for c in bytes.chunks_exact(4) {
                out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
        }
        DType::F16 => {
            for c in bytes.chunks_exact(2) {
                out.push(F16(u16::from_le_bytes([c[0], c[1]])).to_f32());
            }
        }
        DType::BF16 => {
            for c in bytes.chunks_exact(2) {
                out.push(BF16(u16::from_le_bytes([c[0], c[1]])).to_f32());
            }
        }
    }
}

impl WireRequest {
    /// Build a request frame from f32 row data (`data.len()` must be a
    /// `rows * n` multiple; rows is derived).
    pub fn from_f32(
        id: u64,
        n: usize,
        data: &[f32],
        kernel: KernelKind,
        dtype: DType,
    ) -> WireRequest {
        let rows = if n == 0 { 0 } else { data.len() / n };
        WireRequest {
            id,
            n: n as u32,
            rows: rows as u32,
            kernel,
            dtype,
            scale: None,
            force_native: false,
            prologue: Prologue::None,
            epilogue: Epilogue::None,
            trace: 0,
            payload: encode_elems(data, dtype),
        }
    }

    /// Decode the payload and build the coordinator-side request. The
    /// payload length is re-checked against `rows * n` so a hand-built
    /// frame can't smuggle a shape mismatch past the router.
    pub fn to_transform(&self) -> Result<TransformRequest, String> {
        let n = self.n as usize;
        let rows = self.rows as usize;
        let want = (self.rows as u64) * (self.n as u64) * self.dtype.size_bytes() as u64;
        if self.payload.len() as u64 != want {
            return Err(format!(
                "payload length {} != rows {} * n {} * {}B",
                self.payload.len(),
                rows,
                n,
                self.dtype.size_bytes()
            ));
        }
        Ok(TransformRequest {
            id: self.id,
            n,
            rows,
            data: decode_elems(&self.payload, self.dtype)?.into(),
            kernel: self.kernel,
            scale: self.scale,
            prologue: self.prologue,
            epilogue: self.epilogue,
            force_native: self.force_native,
            trace: TraceCtx(self.trace),
        })
    }
}

impl WireResponse {
    /// Build a response frame from a coordinator response, encoding the
    /// payload in the request's wire dtype. `n` comes from the request
    /// the server tracked for this id.
    pub fn from_transform(resp: &TransformResponse, n: u32, dtype: DType) -> WireResponse {
        let rows = if n == 0 { 0 } else { resp.data.len() / n as usize };
        WireResponse {
            id: resp.id,
            n,
            rows: rows as u32,
            dtype,
            pjrt: resp.backend == "pjrt",
            batch_rows: resp.batch_rows as u32,
            queue_us: resp.queue_us,
            exec_us: resp.exec_us,
            scales: resp.scales.clone(),
            payload: encode_elems(&resp.data, dtype),
        }
    }

    /// Decode the payload back to f32 values.
    pub fn data_f32(&self) -> Result<Vec<f32>, String> {
        decode_elems(&self.payload, self.dtype)
    }

    /// Backend label, mirroring [`TransformResponse::backend`].
    pub fn backend(&self) -> &'static str {
        if self.pjrt {
            "pjrt"
        } else {
            "native"
        }
    }
}

// ---------------------------------------------------------------------
// Encoding.

fn kernel_tag(k: KernelKind) -> u8 {
    match k {
        KernelKind::Scalar => 0,
        KernelKind::Dao => 1,
        KernelKind::HadaCore => 2,
    }
}

fn kernel_from_tag(t: u8) -> Result<KernelKind, String> {
    match t {
        0 => Ok(KernelKind::Scalar),
        1 => Ok(KernelKind::Dao),
        2 => Ok(KernelKind::HadaCore),
        _ => Err(format!("unknown kernel tag {t}")),
    }
}

fn dtype_tag(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::BF16 => 2,
    }
}

fn dtype_from_tag(t: u8) -> Result<DType, String> {
    match t {
        0 => Ok(DType::F32),
        1 => Ok(DType::F16),
        2 => Ok(DType::BF16),
        _ => Err(format!("unknown dtype tag {t}")),
    }
}

fn epilogue_tags(e: Epilogue) -> (u8, u32) {
    match e {
        Epilogue::None => (0, 0),
        Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 } => (1, 0),
        Epilogue::QuantFp8 { fmt: Fp8Format::E5M2 } => (2, 0),
        Epilogue::QuantInt8 { group } => (3, group as u32),
    }
}

fn epilogue_from_tags(tag: u8, group: u32) -> Result<Epilogue, String> {
    match tag {
        0 | 1 | 2 if group != 0 => {
            Err(format!("epilogue tag {tag} must carry group 0, got {group}"))
        }
        0 => Ok(Epilogue::None),
        1 => Ok(Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 }),
        2 => Ok(Epilogue::QuantFp8 { fmt: Fp8Format::E5M2 }),
        3 if group == 0 => Err("int8 epilogue requires a nonzero group".to_string()),
        3 => Ok(Epilogue::QuantInt8 { group: group as usize }),
        _ => Err(format!("unknown epilogue tag {tag}")),
    }
}

const TAG_REQUEST: u8 = 1;
const TAG_RESPONSE: u8 = 2;
const TAG_ERROR: u8 = 3;
const TAG_BUSY: u8 = 4;
const TAG_PING: u8 = 5;
const TAG_PONG: u8 = 6;
const TAG_STATS_REQUEST: u8 = 7;
const TAG_STATS: u8 = 8;
const TAG_STATS_TEXT_REQUEST: u8 = 9;
const TAG_STATS_TEXT: u8 = 10;
const TAG_TRACE_REQUEST: u8 = 11;
const TAG_TRACE_DUMP: u8 = 12;

const FLAG_HAS_SCALE: u8 = 1 << 0;
const FLAG_FORCE_NATIVE: u8 = 1 << 1;
const FLAG_HAS_PROLOGUE_SEED: u8 = 1 << 2;
const FLAG_HAS_TRACE: u8 = 1 << 3;

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

impl Frame {
    /// Encode the whole frame, length prefix included.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::with_capacity(64);
        body.push(WIRE_VERSION);
        match self {
            Frame::Request(r) => {
                body.push(TAG_REQUEST);
                put_u64(&mut body, r.id);
                put_u32(&mut body, r.n);
                put_u32(&mut body, r.rows);
                body.push(kernel_tag(r.kernel));
                body.push(dtype_tag(r.dtype));
                let mut flags = 0u8;
                if r.scale.is_some() {
                    flags |= FLAG_HAS_SCALE;
                }
                if r.force_native {
                    flags |= FLAG_FORCE_NATIVE;
                }
                if !r.prologue.is_none() {
                    flags |= FLAG_HAS_PROLOGUE_SEED;
                }
                if r.trace != 0 {
                    flags |= FLAG_HAS_TRACE;
                }
                body.push(flags);
                let (etag, group) = epilogue_tags(r.epilogue);
                body.push(etag);
                put_u32(&mut body, group);
                put_f32(&mut body, r.scale.unwrap_or(0.0));
                // the seed and trace fields only exist under their
                // flags, so plain frames stay byte-identical to the
                // pre-prologue / pre-trace layouts
                if let Prologue::SignFlip { seed } = r.prologue {
                    put_u64(&mut body, seed);
                }
                if r.trace != 0 {
                    put_u64(&mut body, r.trace);
                }
                body.extend_from_slice(&r.payload);
            }
            Frame::Response(r) => {
                body.push(TAG_RESPONSE);
                put_u64(&mut body, r.id);
                put_u32(&mut body, r.n);
                put_u32(&mut body, r.rows);
                body.push(dtype_tag(r.dtype));
                body.push(r.pjrt as u8);
                put_u32(&mut body, r.batch_rows);
                put_u64(&mut body, r.queue_us);
                put_u64(&mut body, r.exec_us);
                match &r.scales {
                    QuantScales::None => body.push(0),
                    QuantScales::PerTensor(s) => {
                        body.push(1);
                        put_f32(&mut body, *s);
                    }
                    QuantScales::PerGroup(v) => {
                        body.push(2);
                        put_u32(&mut body, v.len() as u32);
                        for s in v {
                            put_f32(&mut body, *s);
                        }
                    }
                }
                body.extend_from_slice(&r.payload);
            }
            Frame::Error(e) => {
                body.push(TAG_ERROR);
                put_u64(&mut body, e.id);
                body.push(e.code.tag());
                // truncate over-long messages on a char boundary so the
                // emitted frame always decodes
                let mut end = e.msg.len().min(u16::MAX as usize);
                while end > 0 && !e.msg.is_char_boundary(end) {
                    end -= 1;
                }
                put_u16(&mut body, end as u16);
                body.extend_from_slice(&e.msg.as_bytes()[..end]);
            }
            Frame::Busy { id, retry_after_us } => {
                body.push(TAG_BUSY);
                put_u64(&mut body, *id);
                put_u32(&mut body, *retry_after_us);
            }
            Frame::Ping { id } => {
                body.push(TAG_PING);
                put_u64(&mut body, *id);
            }
            Frame::Pong { id } => {
                body.push(TAG_PONG);
                put_u64(&mut body, *id);
            }
            Frame::StatsRequest { id } => {
                body.push(TAG_STATS_REQUEST);
                put_u64(&mut body, *id);
            }
            Frame::Stats(s) => {
                body.push(TAG_STATS);
                put_u64(&mut body, s.id);
                put_u32(&mut body, s.counters.len() as u32);
                for (k, v) in &s.counters {
                    // keys are 1..=255 bytes on the wire; clamp rather
                    // than panic on degenerate caller input
                    let kb = if k.is_empty() { b"?" } else { k.as_bytes() };
                    let len = kb.len().min(u8::MAX as usize);
                    body.push(len as u8);
                    body.extend_from_slice(&kb[..len]);
                    put_u64(&mut body, *v);
                }
                let rb = s.report.as_bytes();
                put_u32(&mut body, rb.len() as u32);
                body.extend_from_slice(rb);
            }
            Frame::StatsTextRequest { id } => {
                body.push(TAG_STATS_TEXT_REQUEST);
                put_u64(&mut body, *id);
            }
            Frame::StatsText { id, text } => {
                body.push(TAG_STATS_TEXT);
                put_u64(&mut body, *id);
                let tb = text.as_bytes();
                put_u32(&mut body, tb.len() as u32);
                body.extend_from_slice(tb);
            }
            Frame::TraceRequest { id, trace } => {
                body.push(TAG_TRACE_REQUEST);
                put_u64(&mut body, *id);
                put_u64(&mut body, *trace);
            }
            Frame::TraceDump { id, events } => {
                body.push(TAG_TRACE_DUMP);
                put_u64(&mut body, *id);
                put_u32(&mut body, events.len() as u32);
                for e in events {
                    put_u64(&mut body, e.trace);
                    body.push(e.stage as u8);
                    put_u32(&mut body, e.arg);
                    put_u64(&mut body, e.t_us);
                }
            }
        }
        let mut out = Vec::with_capacity(4 + body.len());
        put_u32(&mut out, body.len() as u32);
        out.extend_from_slice(&body);
        out
    }

    /// The id this frame refers to (every frame type carries one).
    pub fn id(&self) -> u64 {
        match self {
            Frame::Request(r) => r.id,
            Frame::Response(r) => r.id,
            Frame::Error(e) => e.id,
            Frame::Busy { id, .. }
            | Frame::Ping { id }
            | Frame::Pong { id }
            | Frame::StatsRequest { id }
            | Frame::StatsTextRequest { id }
            | Frame::StatsText { id, .. }
            | Frame::TraceRequest { id, .. }
            | Frame::TraceDump { id, .. } => *id,
            Frame::Stats(s) => s.id,
        }
    }
}

// ---------------------------------------------------------------------
// Decoding.

/// Bounded cursor over a frame body. Every read is checked; overruns
/// surface as `Err`, never panics.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, len: usize) -> Result<&'a [u8], String> {
        if self.remaining() < len {
            return Err(format!(
                "truncated body: need {len} bytes, have {}",
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32_bits(&mut self) -> Result<u32, String> {
        self.u32()
    }

    fn utf8(&mut self, len: usize) -> Result<String, String> {
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "invalid utf-8".to_string())
    }

    fn finish(self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!("{} trailing bytes after frame body", self.remaining()));
        }
        Ok(())
    }
}

/// The fixed-size fields of a request body, parsed up to (but not
/// including) the payload bytes. Shared by [`parse_body`] and the
/// server's pooled decode ([`decode_server_frame`]) so the two paths can
/// never drift: one strict parser, two payload destinations.
struct ReqHeader {
    id: u64,
    n: u32,
    rows: u32,
    kernel: KernelKind,
    dtype: DType,
    scale: Option<f32>,
    force_native: bool,
    prologue: Prologue,
    epilogue: Epilogue,
    trace: u64,
}

/// Parse a request body's header fields and validate that exactly
/// `rows * n * elem_size` payload bytes remain in the cursor.
fn parse_request_header(c: &mut Cursor) -> Result<ReqHeader, String> {
    let id = c.u64()?;
    let n = c.u32()?;
    let rows = c.u32()?;
    let kernel = kernel_from_tag(c.u8()?)?;
    let dtype = dtype_from_tag(c.u8()?)?;
    let flags = c.u8()?;
    if flags & !(FLAG_HAS_SCALE | FLAG_FORCE_NATIVE | FLAG_HAS_PROLOGUE_SEED | FLAG_HAS_TRACE) != 0
    {
        return Err(format!("unknown request flags {flags:#x}"));
    }
    let etag = c.u8()?;
    let group = c.u32()?;
    let epilogue = epilogue_from_tags(etag, group)?;
    let scale_bits = c.f32_bits()?;
    let scale = if flags & FLAG_HAS_SCALE != 0 {
        Some(f32::from_bits(scale_bits))
    } else {
        if scale_bits != 0 {
            return Err("scale bits set without the scale flag".to_string());
        }
        None
    };
    let prologue = if flags & FLAG_HAS_PROLOGUE_SEED != 0 {
        Prologue::SignFlip { seed: c.u64()? }
    } else {
        Prologue::None
    };
    let trace = if flags & FLAG_HAS_TRACE != 0 {
        let t = c.u64()?;
        if t == 0 {
            return Err("zero trace id under the trace flag".to_string());
        }
        t
    } else {
        0
    };
    let want = (rows as u64) * (n as u64) * dtype.size_bytes() as u64;
    if c.remaining() as u64 != want {
        return Err(format!(
            "request payload is {} bytes, want rows {rows} * n {n} * {}B = {want}",
            c.remaining(),
            dtype.size_bytes()
        ));
    }
    Ok(ReqHeader {
        id,
        n,
        rows,
        kernel,
        dtype,
        scale,
        force_native: flags & FLAG_FORCE_NATIVE != 0,
        prologue,
        epilogue,
        trace,
    })
}

/// Parse one frame body (the bytes after the length prefix).
pub fn parse_body(body: &[u8]) -> Result<Frame, String> {
    let mut c = Cursor::new(body);
    let version = c.u8()?;
    if version != WIRE_VERSION {
        return Err(format!("unsupported wire version {version} (want {WIRE_VERSION})"));
    }
    let tag = c.u8()?;
    let frame = match tag {
        TAG_REQUEST => {
            let h = parse_request_header(&mut c)?;
            let payload_len = c.remaining();
            let payload = c.take(payload_len)?.to_vec();
            c.finish()?;
            Frame::Request(WireRequest {
                id: h.id,
                n: h.n,
                rows: h.rows,
                kernel: h.kernel,
                dtype: h.dtype,
                scale: h.scale,
                force_native: h.force_native,
                prologue: h.prologue,
                epilogue: h.epilogue,
                trace: h.trace,
                payload,
            })
        }
        TAG_RESPONSE => {
            let id = c.u64()?;
            let n = c.u32()?;
            let rows = c.u32()?;
            let dtype = dtype_from_tag(c.u8()?)?;
            let pjrt = match c.u8()? {
                0 => false,
                1 => true,
                b => return Err(format!("unknown backend tag {b}")),
            };
            let batch_rows = c.u32()?;
            let queue_us = c.u64()?;
            let exec_us = c.u64()?;
            let scales = match c.u8()? {
                0 => QuantScales::None,
                1 => QuantScales::PerTensor(f32::from_bits(c.f32_bits()?)),
                2 => {
                    let count = c.u32()? as usize;
                    if count * 4 > c.remaining() {
                        return Err(format!(
                            "per-group scale count {count} exceeds frame"
                        ));
                    }
                    let mut v = Vec::with_capacity(count);
                    for _ in 0..count {
                        v.push(f32::from_bits(c.f32_bits()?));
                    }
                    QuantScales::PerGroup(v)
                }
                t => return Err(format!("unknown scales tag {t}")),
            };
            let want = (rows as u64) * (n as u64) * dtype.size_bytes() as u64;
            if c.remaining() as u64 != want {
                return Err(format!(
                    "response payload is {} bytes, want {want}",
                    c.remaining()
                ));
            }
            let payload = c.take(want as usize)?.to_vec();
            c.finish()?;
            Frame::Response(WireResponse {
                id,
                n,
                rows,
                dtype,
                pjrt,
                batch_rows,
                queue_us,
                exec_us,
                scales,
                payload,
            })
        }
        TAG_ERROR => {
            let id = c.u64()?;
            let code = ErrorCode::from_tag(c.u8()?)?;
            let len = c.u16()? as usize;
            let msg = c.utf8(len)?;
            c.finish()?;
            Frame::Error(WireError { id, code, msg })
        }
        TAG_BUSY => {
            let id = c.u64()?;
            let retry_after_us = c.u32()?;
            c.finish()?;
            Frame::Busy { id, retry_after_us }
        }
        TAG_PING => {
            let id = c.u64()?;
            c.finish()?;
            Frame::Ping { id }
        }
        TAG_PONG => {
            let id = c.u64()?;
            c.finish()?;
            Frame::Pong { id }
        }
        TAG_STATS_REQUEST => {
            let id = c.u64()?;
            c.finish()?;
            Frame::StatsRequest { id }
        }
        TAG_STATS => {
            let id = c.u64()?;
            let count = c.u32()?;
            if count > MAX_STATS_COUNTERS {
                return Err(format!("stats counter count {count} exceeds cap"));
            }
            let mut counters = Vec::with_capacity(count as usize);
            for _ in 0..count {
                let klen = c.u8()? as usize;
                if klen == 0 {
                    return Err("empty stats counter key".to_string());
                }
                let key = c.utf8(klen)?;
                let value = c.u64()?;
                counters.push((key, value));
            }
            let rlen = c.u32()? as usize;
            if rlen > c.remaining() {
                return Err(format!("stats report length {rlen} exceeds frame"));
            }
            let report = c.utf8(rlen)?;
            c.finish()?;
            Frame::Stats(WireStats { id, counters, report })
        }
        TAG_STATS_TEXT_REQUEST => {
            let id = c.u64()?;
            c.finish()?;
            Frame::StatsTextRequest { id }
        }
        TAG_STATS_TEXT => {
            let id = c.u64()?;
            let tlen = c.u32()? as usize;
            if tlen > c.remaining() {
                return Err(format!("stats text length {tlen} exceeds frame"));
            }
            let text = c.utf8(tlen)?;
            c.finish()?;
            Frame::StatsText { id, text }
        }
        TAG_TRACE_REQUEST => {
            let id = c.u64()?;
            let trace = c.u64()?;
            c.finish()?;
            Frame::TraceRequest { id, trace }
        }
        TAG_TRACE_DUMP => {
            let id = c.u64()?;
            let count = c.u32()?;
            if count > MAX_TRACE_EVENTS {
                return Err(format!("trace event count {count} exceeds cap"));
            }
            // 21 bytes per event; reject before allocating on a lying
            // count (same discipline as the per-group scales above)
            if (count as usize) * 21 > c.remaining() {
                return Err(format!("trace event count {count} exceeds frame"));
            }
            let mut events = Vec::with_capacity(count as usize);
            for _ in 0..count {
                let trace = c.u64()?;
                let stage = Stage::from_u8(c.u8()?)
                    .ok_or_else(|| "unknown trace stage".to_string())?;
                let arg = c.u32()?;
                let t_us = c.u64()?;
                events.push(SpanEvent { trace, stage, arg, t_us });
            }
            c.finish()?;
            Frame::TraceDump { id, events }
        }
        _ => return Err(format!("unknown frame tag {tag}")),
    };
    Ok(frame)
}

/// Decode one frame from the front of `buf`.
///
/// * `Ok(None)` — `buf` holds a valid prefix of a frame; read more bytes.
/// * `Ok(Some((frame, consumed)))` — one frame decoded; `consumed` bytes
///   (length prefix included) were used.
/// * `Err(msg)` — the bytes can never become a valid frame (bad length,
///   bad version/tag/fields); the connection should answer with an error
///   frame and close.
pub fn decode_frame(
    buf: &[u8],
    max_frame_bytes: u32,
) -> Result<Option<(Frame, usize)>, String> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if len < 2 {
        return Err(format!("frame length {len} is shorter than the header"));
    }
    if len > max_frame_bytes {
        return Err(format!("frame length {len} exceeds cap {max_frame_bytes}"));
    }
    let total = 4 + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    let frame = parse_body(&buf[4..total])?;
    Ok(Some((frame, total)))
}

// ---------------------------------------------------------------------
// The server's zero-copy request/response path.

/// A transform request decoded **directly into a pooled buffer**: the
/// payload bytes are widened to f32 in the same pass that parses the
/// frame, landing in a [`PooledBuf`] from the server's pool — the one
/// and only time the payload is materialised. No intermediate
/// `Vec<u8>` payload copy, no second `Vec<f32>` decode.
#[derive(Debug)]
pub struct PooledRequest {
    /// Client-assigned id.
    pub id: u64,
    /// Hadamard size.
    pub n: u32,
    /// Row count.
    pub rows: u32,
    /// Kernel implementation to run.
    pub kernel: KernelKind,
    /// The wire dtype the payload arrived in (and the response returns).
    pub dtype: DType,
    /// Output scaling (`None` = orthonormal).
    pub scale: Option<f32>,
    /// Force the native backend.
    pub force_native: bool,
    /// Fused sign-flip prologue.
    pub prologue: Prologue,
    /// Fused quantize epilogue.
    pub epilogue: Epilogue,
    /// Span-trace id from the wire (0 = none; the conn reader may still
    /// sample a fresh one at admission).
    pub trace: u64,
    /// The decoded f32 payload, pool-affiliated: it travels into the
    /// coordinator, is transformed in place, comes back in the response,
    /// is framed from directly, and returns to the pool on drop.
    pub data: PooledBuf,
}

impl PooledRequest {
    /// Hand the buffer to the coordinator. Shape consistency was
    /// enforced during decode (strict `rows * n * elem_size` check).
    pub fn into_transform(self) -> TransformRequest {
        TransformRequest {
            id: self.id,
            n: self.n as usize,
            rows: self.rows as usize,
            data: self.data,
            kernel: self.kernel,
            scale: self.scale,
            prologue: self.prologue,
            epilogue: self.epilogue,
            force_native: self.force_native,
            trace: TraceCtx(self.trace),
        }
    }
}

/// What [`decode_server_frame`] yields: requests take the pooled fast
/// path, everything else decodes as a regular [`Frame`].
#[derive(Debug)]
pub enum ServerFrame {
    /// A transform request, payload already widened into a pooled buffer.
    Request(PooledRequest),
    /// Any other frame (ping, stats, or a client-misdirected frame the
    /// connection loop answers with an error).
    Control(Frame),
}

/// [`decode_frame`] specialised for the server's connection loop:
/// request payloads decode straight into a buffer from `pool` (one
/// widening pass, zero intermediate copies); every other frame falls
/// back to [`parse_body`]. Same strictness, same `Ok(None)` = "need
/// more bytes" contract.
pub fn decode_server_frame(
    buf: &[u8],
    max_frame_bytes: u32,
    pool: &BufferPool,
) -> Result<Option<(ServerFrame, usize)>, String> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if len < 2 {
        return Err(format!("frame length {len} is shorter than the header"));
    }
    if len > max_frame_bytes {
        return Err(format!("frame length {len} exceeds cap {max_frame_bytes}"));
    }
    let total = 4 + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    let body = &buf[4..total];
    let mut c = Cursor::new(body);
    let version = c.u8()?;
    if version != WIRE_VERSION {
        return Err(format!("unsupported wire version {version} (want {WIRE_VERSION})"));
    }
    if c.u8()? != TAG_REQUEST {
        let frame = parse_body(body)?;
        return Ok(Some((ServerFrame::Control(frame), total)));
    }
    let h = parse_request_header(&mut c)?;
    let elems = (h.rows as usize) * (h.n as usize);
    let mut data = pool.get(elems);
    let payload_len = c.remaining();
    // the header parser proved payload_len == rows * n * elem_size, so
    // this extends exactly `elems` values into the reserved capacity —
    // no reallocation on a pooled (shelf-hit) buffer
    widen_into(c.take(payload_len)?, h.dtype, &mut data);
    c.finish()?;
    Ok(Some((
        ServerFrame::Request(PooledRequest {
            id: h.id,
            n: h.n,
            rows: h.rows,
            kernel: h.kernel,
            dtype: h.dtype,
            scale: h.scale,
            force_native: h.force_native,
            prologue: h.prologue,
            epilogue: h.epilogue,
            trace: h.trace,
            data,
        }),
        total,
    )))
}

/// Reusable response-frame builder for the server's writer thread: the
/// length prefix, response header, and scale fields build into a
/// retained scratch, and the payload is **a view of the response's own
/// buffer** whenever the dtype allows (f32 on little-endian hosts: the
/// wire format *is* the in-memory IEEE bit pattern). 16-bit dtypes (and
/// big-endian hosts) narrow into a second retained scratch. Either way,
/// framing a response performs no heap allocation in steady state —
/// pair with [`write_frame_parts`] to put both parts on the socket in
/// one vectored write.
///
/// The emitted bytes are identical to
/// `Frame::Response(WireResponse::from_transform(..)).encode()` —
/// enforced by this module's `framer_matches_frame_encode` test.
#[derive(Debug, Default)]
pub struct ResponseFramer {
    header: Vec<u8>,
    payload: Vec<u8>,
}

impl ResponseFramer {
    /// An empty framer (scratch grows to steady size on first use).
    pub fn new() -> ResponseFramer {
        ResponseFramer { header: Vec::with_capacity(96), payload: Vec::new() }
    }

    /// Frame `resp` for the wire: returns `(prefix, payload)` where
    /// `prefix` is the length prefix + full response header (scales
    /// included) and `payload` is the encoded element bytes. Valid until
    /// the next `frame` call.
    pub fn frame<'a>(
        &'a mut self,
        resp: &'a TransformResponse,
        n: u32,
        dtype: DType,
    ) -> (&'a [u8], &'a [u8]) {
        let rows = if n == 0 { 0 } else { resp.data.len() / n as usize };
        self.header.clear();
        put_u32(&mut self.header, 0); // length prefix, patched below
        self.header.push(WIRE_VERSION);
        self.header.push(TAG_RESPONSE);
        put_u64(&mut self.header, resp.id);
        put_u32(&mut self.header, n);
        put_u32(&mut self.header, rows as u32);
        self.header.push(dtype_tag(dtype));
        self.header.push((resp.backend == "pjrt") as u8);
        put_u32(&mut self.header, resp.batch_rows as u32);
        put_u64(&mut self.header, resp.queue_us);
        put_u64(&mut self.header, resp.exec_us);
        match &resp.scales {
            QuantScales::None => self.header.push(0),
            QuantScales::PerTensor(s) => {
                self.header.push(1);
                put_f32(&mut self.header, *s);
            }
            QuantScales::PerGroup(v) => {
                self.header.push(2);
                put_u32(&mut self.header, v.len() as u32);
                for s in v {
                    put_f32(&mut self.header, *s);
                }
            }
        }
        // f32 payloads on little-endian hosts go out as a raw view of
        // the response buffer: `f32::to_le_bytes` is exactly the
        // in-memory representation, so the bytes are identical to
        // `encode_elems` without the copy
        let direct = dtype == DType::F32 && cfg!(target_endian = "little");
        if !direct {
            self.payload.clear();
            match dtype {
                DType::F32 => {
                    for v in resp.data.iter() {
                        self.payload.extend_from_slice(&v.to_le_bytes());
                    }
                }
                DType::F16 => {
                    for v in resp.data.iter() {
                        self.payload
                            .extend_from_slice(&F16::from_f32(*v).0.to_le_bytes());
                    }
                }
                DType::BF16 => {
                    for v in resp.data.iter() {
                        self.payload
                            .extend_from_slice(&BF16::from_f32(*v).0.to_le_bytes());
                    }
                }
            }
        }
        let payload: &[u8] = if direct {
            // SAFETY: f32 has no padding or invalid bit patterns; the
            // view covers exactly the buffer's initialised elements and
            // lives as long as `resp`'s borrow.
            unsafe {
                std::slice::from_raw_parts(
                    resp.data.as_ptr().cast::<u8>(),
                    resp.data.len() * 4,
                )
            }
        } else {
            &self.payload
        };
        let body_len = (self.header.len() - 4) + payload.len();
        self.header[..4].copy_from_slice(&(body_len as u32).to_le_bytes());
        (&self.header, payload)
    }
}

/// Write a two-part frame ([`ResponseFramer::frame`] output) with one
/// vectored syscall attempt, falling back to `write_all` for any
/// remainder — the header and the payload hit the socket without ever
/// being joined into a contiguous allocation.
pub fn write_frame_parts<W: std::io::Write>(
    w: &mut W,
    header: &[u8],
    payload: &[u8],
) -> std::io::Result<()> {
    use std::io::IoSlice;
    let mut wrote =
        w.write_vectored(&[IoSlice::new(header), IoSlice::new(payload)])?;
    if wrote >= header.len() + payload.len() {
        return Ok(());
    }
    if wrote < header.len() {
        w.write_all(&header[wrote..])?;
        wrote = header.len();
    }
    w.write_all(&payload[wrote - header.len()..])
}

/// Failure modes of [`read_frame`].
#[derive(Debug)]
pub enum ReadError {
    /// Transport failure (including EOF and read timeouts; the caller
    /// inspects [`std::io::Error::kind`]).
    Io(std::io::Error),
    /// The peer sent bytes that cannot be a valid frame.
    Malformed(String),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Io(e) => write!(f, "io: {e}"),
            ReadError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

/// Read one frame from a blocking reader (the server/client transport
/// path). The length prefix is validated against `max_frame_bytes`
/// before the body allocation.
pub fn read_frame<R: std::io::Read>(
    r: &mut R,
    max_frame_bytes: u32,
) -> Result<Frame, ReadError> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes).map_err(ReadError::Io)?;
    let len = u32::from_le_bytes(len_bytes);
    if len < 2 {
        return Err(ReadError::Malformed(format!(
            "frame length {len} is shorter than the header"
        )));
    }
    if len > max_frame_bytes {
        return Err(ReadError::Malformed(format!(
            "frame length {len} exceeds cap {max_frame_bytes}"
        )));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).map_err(ReadError::Io)?;
    parse_body(&body).map_err(ReadError::Malformed)
}

/// Write one frame to a blocking writer.
pub fn write_frame<W: std::io::Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&frame.encode())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_frame() -> Frame {
        Frame::Request(WireRequest::from_f32(
            7,
            8,
            &[1.0, -2.5, 0.25, 3.0, -0.5, 8.0, 0.0, -1.0],
            KernelKind::HadaCore,
            DType::F32,
        ))
    }

    #[test]
    fn request_roundtrip_bit_exact() {
        let frame = req_frame();
        let bytes = frame.encode();
        let (decoded, used) = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES)
            .unwrap()
            .unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(decoded, frame);
    }

    #[test]
    fn all_frame_types_roundtrip() {
        let frames = vec![
            req_frame(),
            Frame::Response(WireResponse {
                id: 9,
                n: 4,
                rows: 2,
                dtype: DType::F16,
                pjrt: true,
                batch_rows: 16,
                queue_us: 120,
                exec_us: 44,
                scales: QuantScales::PerGroup(vec![0.5, 2.0]),
                payload: encode_elems(&[1.0; 8], DType::F16),
            }),
            Frame::Error(WireError {
                id: 3,
                code: ErrorCode::Rejected,
                msg: "n=10 unsupported".to_string(),
            }),
            Frame::Busy { id: 11, retry_after_us: 500 },
            Frame::Ping { id: 1 },
            Frame::Pong { id: 1 },
            Frame::StatsRequest { id: 5 },
            Frame::Stats(WireStats {
                id: 5,
                counters: vec![("submitted".into(), 10), ("e2e_p99_us".into(), 800)],
                report: "requests: 10 submitted\n".to_string(),
            }),
            Frame::StatsTextRequest { id: 6 },
            Frame::StatsText {
                id: 6,
                text: "# TYPE hadacore_requests_total counter\nhadacore_requests_total 10\n"
                    .to_string(),
            },
            Frame::TraceRequest { id: 12, trace: 0xFACE },
            Frame::TraceDump {
                id: 12,
                events: vec![
                    SpanEvent { trace: 0xFACE, stage: Stage::Decode, arg: 4, t_us: 10 },
                    SpanEvent { trace: 0xFACE, stage: Stage::Written, arg: 0, t_us: 95 },
                ],
            },
            Frame::TraceDump { id: 13, events: vec![] },
        ];
        for frame in frames {
            let bytes = frame.encode();
            let (decoded, used) = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES)
                .unwrap()
                .unwrap();
            assert_eq!(used, bytes.len(), "{frame:?}");
            assert_eq!(decoded, frame);
        }
    }

    #[test]
    fn scale_epilogue_and_flags_roundtrip() {
        let mut r = match req_frame() {
            Frame::Request(r) => r,
            _ => unreachable!(),
        };
        r.scale = Some(2.5);
        r.force_native = true;
        r.epilogue = Epilogue::QuantInt8 { group: 4 };
        r.prologue = Prologue::SignFlip { seed: 0xDEAD_BEEF_CAFE_F00D };
        let frame = Frame::Request(r);
        let bytes = frame.encode();
        let (decoded, _) = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES)
            .unwrap()
            .unwrap();
        assert_eq!(decoded, frame);
    }

    #[test]
    fn prologue_seed_roundtrips_and_plain_frames_keep_the_v1_layout() {
        // every seed value round-trips, including the 0 and max sentinels
        for seed in [0u64, 1, u64::MAX, 0x5EED_0006] {
            let mut r = match req_frame() {
                Frame::Request(r) => r,
                _ => unreachable!(),
            };
            r.prologue = Prologue::SignFlip { seed };
            let frame = Frame::Request(r);
            let bytes = frame.encode();
            let (decoded, _) = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES)
                .unwrap()
                .unwrap();
            assert_eq!(decoded, frame, "seed={seed:#x}");
            match decoded {
                Frame::Request(d) => {
                    assert_eq!(d.to_transform().unwrap().prologue,
                        Prologue::SignFlip { seed });
                }
                _ => unreachable!(),
            }
        }
        // the seed field only exists under its flag: a plain request is
        // exactly 8 bytes shorter and stays decodable by a pre-prologue
        // peer (backward-compatible layout)
        let plain = req_frame().encode();
        let mut r = match req_frame() {
            Frame::Request(r) => r,
            _ => unreachable!(),
        };
        r.prologue = Prologue::SignFlip { seed: 7 };
        let rotated = Frame::Request(r).encode();
        assert_eq!(rotated.len(), plain.len() + 8);
    }

    #[test]
    fn trace_flag_roundtrips_and_plain_frames_keep_the_v1_layout() {
        // nonzero trace ids round-trip, alone and alongside a seed
        for (trace, seed) in [(1u64, None), (u64::MAX, None), (0x7ACE, Some(9u64))] {
            let mut r = match req_frame() {
                Frame::Request(r) => r,
                _ => unreachable!(),
            };
            r.trace = trace;
            if let Some(s) = seed {
                r.prologue = Prologue::SignFlip { seed: s };
            }
            let frame = Frame::Request(r);
            let bytes = frame.encode();
            let (decoded, _) = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES)
                .unwrap()
                .unwrap();
            assert_eq!(decoded, frame, "trace={trace:#x}");
            match decoded {
                Frame::Request(d) => {
                    assert_eq!(d.to_transform().unwrap().trace, TraceCtx(trace));
                }
                _ => unreachable!(),
            }
        }
        // the trace field only exists under its flag: an untraced
        // request is exactly 8 bytes shorter and stays decodable by a
        // pre-trace peer (same backward-compatible trick as the seed)
        let plain = req_frame().encode();
        let mut r = match req_frame() {
            Frame::Request(r) => r,
            _ => unreachable!(),
        };
        r.trace = 0x7ACE;
        let traced = Frame::Request(r).encode();
        assert_eq!(traced.len(), plain.len() + 8);
        // a zero trace id under the flag is malformed (it would decode
        // as "sampled" with the unsampled sentinel)
        let mut b = traced;
        let flags_at = 4 + 2 + 8 + 4 + 4 + 1 + 1; // prefix,ver+tag,id,n,rows,kernel,dtype
        assert_eq!(b[flags_at] & FLAG_HAS_TRACE, FLAG_HAS_TRACE);
        let trace_at = flags_at + 1 + 1 + 4 + 4; // flags,epilogue,group,scale
        b[trace_at..trace_at + 8].copy_from_slice(&0u64.to_le_bytes());
        assert!(decode_frame(&b, DEFAULT_MAX_FRAME_BYTES).is_err());
    }

    #[test]
    fn incomplete_prefixes_ask_for_more() {
        let bytes = req_frame().encode();
        for cut in 0..bytes.len() {
            let r = decode_frame(&bytes[..cut], DEFAULT_MAX_FRAME_BYTES).unwrap();
            assert!(r.is_none(), "prefix of {cut} bytes must be incomplete");
        }
    }

    #[test]
    fn malformed_bodies_error_without_panicking() {
        let good = req_frame().encode();

        // bad version
        let mut b = good.clone();
        b[4] = 9;
        assert!(decode_frame(&b, DEFAULT_MAX_FRAME_BYTES).is_err());

        // unknown tag
        let mut b = good.clone();
        b[5] = 200;
        assert!(decode_frame(&b, DEFAULT_MAX_FRAME_BYTES).is_err());

        // trailing byte: extend the body and bump the length prefix
        let mut b = good.clone();
        b.push(0);
        let len = (b.len() - 4) as u32;
        b[..4].copy_from_slice(&len.to_le_bytes());
        assert!(decode_frame(&b, DEFAULT_MAX_FRAME_BYTES).is_err());

        // declared length below the 2-byte header
        let mut b = good.clone();
        b[..4].copy_from_slice(&1u32.to_le_bytes());
        assert!(decode_frame(&b, DEFAULT_MAX_FRAME_BYTES).is_err());

        // oversized declared length
        let mut b = good;
        b[..4].copy_from_slice(&(DEFAULT_MAX_FRAME_BYTES + 1).to_le_bytes());
        assert!(decode_frame(&b, DEFAULT_MAX_FRAME_BYTES).is_err());
    }

    #[test]
    fn payload_length_mismatch_is_malformed() {
        let mut r = match req_frame() {
            Frame::Request(r) => r,
            _ => unreachable!(),
        };
        r.rows = 3; // payload holds 1 row of 8
        let bytes = Frame::Request(r).encode();
        let err = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES).unwrap_err();
        assert!(err.contains("payload"), "got: {err}");
    }

    #[test]
    fn scale_without_flag_is_malformed() {
        // hand-corrupt the scale field of a no-scale request
        let bytes = req_frame().encode();
        // body layout: ver(1) tag(1) id(8) n(4) rows(4) kernel(1) dtype(1)
        // flags(1) epi(1) group(4) scale(4) -> scale at body offset 26
        let mut b = bytes;
        b[4 + 26] = 1;
        let err = decode_frame(&b, DEFAULT_MAX_FRAME_BYTES).unwrap_err();
        assert!(err.contains("scale"), "got: {err}");
    }

    #[test]
    fn elems_roundtrip_all_dtypes() {
        let data = [1.5f32, -0.25, 448.0, 1e-4, -3.75, 0.0];
        for dtype in [DType::F32, DType::F16, DType::BF16] {
            let bytes = encode_elems(&data, dtype);
            assert_eq!(bytes.len(), data.len() * dtype.size_bytes());
            let back = decode_elems(&bytes, dtype).unwrap();
            // canonical form: narrow once, widen — re-encoding is stable
            let canon = encode_elems(&back, dtype);
            assert_eq!(bytes, canon, "{dtype:?} encode must be idempotent");
            if dtype == DType::F32 {
                assert_eq!(back, data, "f32 must be bit-exact");
            }
        }
        assert!(decode_elems(&[0u8; 3], DType::F32).is_err());
        assert!(decode_elems(&[0u8; 3], DType::F16).is_err());
    }

    #[test]
    fn to_transform_checks_shape() {
        let r = WireRequest::from_f32(1, 4, &[0.0; 8], KernelKind::Dao, DType::F32);
        let t = r.to_transform().unwrap();
        assert_eq!((t.n, t.rows), (4, 2));
        assert_eq!(t.kernel, KernelKind::Dao);

        let mut bad = r;
        bad.rows = 5;
        assert!(bad.to_transform().is_err());
    }

    #[test]
    fn pooled_decode_matches_parse_body_on_identical_bytes() {
        let pool = BufferPool::new(8);
        let mut variants = Vec::new();
        for dtype in [DType::F32, DType::F16, DType::BF16] {
            let mut r = WireRequest::from_f32(
                42,
                8,
                &[1.0, -2.5, 0.25, 3.0, -0.5, 8.0, 0.0, -1.0],
                KernelKind::HadaCore,
                dtype,
            );
            r.scale = Some(2.5);
            r.force_native = true;
            r.prologue = Prologue::SignFlip { seed: 0x5EED };
            r.epilogue = Epilogue::QuantInt8 { group: 4 };
            variants.push(r);
        }
        variants.push(req_frame_inner());
        for wr in variants {
            let bytes = Frame::Request(wr.clone()).encode();
            let want = wr.to_transform().unwrap();
            let (sf, used) =
                decode_server_frame(&bytes, DEFAULT_MAX_FRAME_BYTES, &pool)
                    .unwrap()
                    .unwrap();
            assert_eq!(used, bytes.len());
            let pr = match sf {
                ServerFrame::Request(pr) => pr,
                other => panic!("want Request, got {other:?}"),
            };
            assert!(pr.data.is_pooled(), "server decode must use the pool");
            let got = pr.into_transform();
            assert_eq!(got.id, want.id);
            assert_eq!((got.n, got.rows), (want.n, want.rows));
            assert_eq!(got.kernel, want.kernel);
            assert_eq!(got.scale, want.scale);
            assert_eq!(got.force_native, want.force_native);
            assert_eq!(got.prologue, want.prologue);
            assert_eq!(got.epilogue, want.epilogue);
            assert_eq!(got.data, want.data, "widened payloads must be bit-equal");
        }
        // non-request frames fall through as Control, byte-compatible
        // with the plain decoder
        let ping = Frame::Ping { id: 3 }.encode();
        match decode_server_frame(&ping, DEFAULT_MAX_FRAME_BYTES, &pool).unwrap() {
            Some((ServerFrame::Control(f), _)) => {
                assert_eq!(f, Frame::Ping { id: 3 })
            }
            other => panic!("want Control(Ping), got {other:?}"),
        }
        // incomplete prefixes still ask for more
        assert!(decode_server_frame(&ping[..3], DEFAULT_MAX_FRAME_BYTES, &pool)
            .unwrap()
            .is_none());
        // malformed input still errors (and leaks no pooled buffer —
        // covered by tests/zero_alloc_pool.rs end to end)
        let mut bad = req_frame().encode();
        bad[4] = 9; // bad version
        assert!(decode_server_frame(&bad, DEFAULT_MAX_FRAME_BYTES, &pool).is_err());
    }

    fn req_frame_inner() -> WireRequest {
        match req_frame() {
            Frame::Request(r) => r,
            _ => unreachable!(),
        }
    }

    #[test]
    fn framer_matches_frame_encode() {
        let mut framer = ResponseFramer::new();
        for dtype in [DType::F32, DType::F16, DType::BF16] {
            for scales in [
                QuantScales::None,
                QuantScales::PerTensor(0.125),
                QuantScales::PerGroup(vec![0.5, 2.0, -1.25, 3.0]),
            ] {
                let resp = TransformResponse {
                    id: 77,
                    data: vec![1.0f32, -2.5, 0.25, 3.0, -0.5, 8.0, 0.0, -1.0]
                        .into(),
                    queue_us: 12,
                    exec_us: 34,
                    batch_rows: 4,
                    backend: "native",
                    scales: scales.clone(),
                };
                let want = Frame::Response(WireResponse::from_transform(
                    &resp,
                    4,
                    dtype,
                ))
                .encode();
                let (header, payload) = framer.frame(&resp, 4, dtype);
                let got: Vec<u8> =
                    header.iter().chain(payload.iter()).copied().collect();
                assert_eq!(got, want, "dtype={dtype:?} scales={scales:?}");
            }
        }
    }

    #[test]
    fn write_frame_parts_concatenates() {
        let mut out = Vec::new();
        write_frame_parts(&mut out, b"head", b"payload").unwrap();
        assert_eq!(out, b"headpayload");
        let mut out = Vec::new();
        write_frame_parts(&mut out, b"", b"p").unwrap();
        assert_eq!(out, b"p");
        let mut out = Vec::new();
        write_frame_parts(&mut out, b"h", b"").unwrap();
        assert_eq!(out, b"h");
    }

    #[test]
    fn read_write_frame_over_a_buffer() {
        let frame = req_frame();
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let decoded = read_frame(&mut cursor, DEFAULT_MAX_FRAME_BYTES).unwrap();
        assert_eq!(decoded, frame);
        // EOF on the drained reader is an Io error, not a panic/hang
        match read_frame(&mut cursor, DEFAULT_MAX_FRAME_BYTES) {
            Err(ReadError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof)
            }
            other => panic!("want EOF, got {other:?}"),
        }
    }
}
