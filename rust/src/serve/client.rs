//! Synchronous pipelining client for the wire protocol.
//!
//! A [`Client`] owns one TCP connection. [`Client::submit`] writes a
//! request frame and returns immediately with a [`PendingReply`]; many
//! submissions can be in flight at once (pipelining), and a background
//! reader thread demultiplexes whatever the server streams back — in
//! completion order, not submission order — by request id. [`Client`]
//! assigns ids itself (unique per connection), so callers never collide
//! with their own in-flight traffic.
//!
//! The blocking conveniences ([`Client::transform`], [`Client::ping`],
//! [`Client::stats`]) are submit-then-wait; [`Reply`] exposes the
//! protocol-level outcomes (`Busy` is data, not a transport error — an
//! open-loop load generator counts it, a latency-sensitive caller backs
//! off and retries). Submission failures are the typed [`ClientError`]:
//! callers building retry/failover logic (the cluster proxy, loadgen)
//! branch on [`ClientError::is_retriable`] and the carried
//! `retry_after_us` hint instead of parsing error strings.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::error::{self as anyhow, anyhow};

use super::wire::{
    decode_frame, write_frame, Frame, WireRequest, WireResponse, WireStats,
    DEFAULT_MAX_FRAME_BYTES,
};

/// What the server answered for one submitted frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// A transform response (the normal case).
    Response(WireResponse),
    /// The request was shed; retry after the hint.
    Busy {
        /// Server-suggested backoff.
        retry_after_us: u32,
    },
    /// An error frame (rejection, execution failure, draining, …).
    Error {
        /// Machine-readable class tag (see [`super::wire::ErrorCode`]).
        code: super::wire::ErrorCode,
        /// Human-readable detail.
        msg: String,
    },
    /// Pong for a ping.
    Pong,
    /// Stats snapshot.
    Stats(WireStats),
    /// Prometheus-style text exposition of the server's registry.
    StatsText(String),
    /// Flight-recorder span events drained from the server.
    TraceDump(Vec<crate::obs::SpanEvent>),
    /// The connection died before the reply arrived.
    Disconnected,
}

/// Typed failure surface of [`Client::submit`] / [`Client::transform`].
///
/// The distinction that matters to callers is *retriability*: a shed
/// (`Busy`), a draining server, and a dead connection are all safe to
/// retry — the transform is a pure function, so resubmitting (here or
/// on another backend) can never double-apply — while a rejection or
/// execution failure is deterministic and retrying it is futile. The
/// cluster proxy's failover path is built directly on this split.
///
/// `ClientError` implements [`std::error::Error`], so it converts into
/// the crate-wide [`anyhow::Error`](crate::util::error::Error) via `?`
/// at call sites that don't care about the distinction.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientError {
    /// The request (or, for an id-0 frame, the whole connection) was
    /// shed by admission control. Retriable after the server's hint.
    Busy {
        /// Server-suggested backoff before retrying.
        retry_after_us: u32,
    },
    /// The server answered an error frame. Retriable only when the
    /// code is [`ErrorCode::Draining`](super::wire::ErrorCode) — the
    /// backend is going away gracefully and another shard can serve
    /// the request.
    Server {
        /// Machine-readable class tag.
        code: super::wire::ErrorCode,
        /// Human-readable detail.
        msg: String,
    },
    /// The connection cannot carry (or no longer carries) the request:
    /// the reader exited, the write failed, or the reply never arrived.
    /// Retriable on a fresh connection.
    Closed {
        /// What happened, for diagnostics.
        detail: String,
    },
    /// The server answered something protocol-legal but senseless for
    /// the call (e.g. a `Pong` for a transform). Not retriable.
    Unexpected {
        /// Debug rendering of the surprise reply.
        detail: String,
    },
}

impl ClientError {
    /// True when resubmitting the same request — to this server or a
    /// different shard — can succeed: shed, draining, or a dead
    /// connection. False for deterministic rejections.
    pub fn is_retriable(&self) -> bool {
        match self {
            ClientError::Busy { .. } | ClientError::Closed { .. } => true,
            ClientError::Server { code, .. } => {
                *code == super::wire::ErrorCode::Draining
            }
            ClientError::Unexpected { .. } => false,
        }
    }

    /// The server's backoff hint, when it sent one.
    pub fn retry_after_us(&self) -> Option<u32> {
        match self {
            ClientError::Busy { retry_after_us } => Some(*retry_after_us),
            _ => None,
        }
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Busy { retry_after_us } => {
                write!(f, "server busy (retry after {retry_after_us}us)")
            }
            ClientError::Server { code, msg } => {
                write!(f, "server error ({code:?}): {msg}")
            }
            ClientError::Closed { detail } => write!(f, "{detail}"),
            ClientError::Unexpected { detail } => {
                write!(f, "unexpected reply {detail}")
            }
        }
    }
}

impl std::error::Error for ClientError {}

/// Handle to one in-flight submission.
pub struct PendingReply {
    /// The id the client assigned to this submission.
    pub id: u64,
    rx: mpsc::Receiver<Reply>,
}

impl PendingReply {
    /// Block until the reply arrives (or the connection dies).
    pub fn wait(self) -> Reply {
        self.rx.recv().unwrap_or(Reply::Disconnected)
    }

    /// Non-blocking poll; `None` while still in flight.
    pub fn try_wait(&self) -> Option<Reply> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Reply::Disconnected),
        }
    }
}

type PendingMap = Arc<Mutex<HashMap<u64, mpsc::Sender<Reply>>>>;

/// One connection to a hadacore server.
pub struct Client {
    writer: Mutex<TcpStream>,
    stream: TcpStream,
    pending: PendingMap,
    /// Set by the reader before it exits (EOF, reset, or a corrupt
    /// stream): the connection can no longer deliver replies, so new
    /// submissions must fail instead of waiting forever.
    dead: Arc<AtomicBool>,
    /// Nonzero once the acceptor shed the *connection* (`Busy` with
    /// id 0): the value is the retry hint in µs. New submissions fail
    /// fast with a typed retriable [`ClientError::Busy`]; requests
    /// already in flight are left to resolve on their own (the server
    /// closes the socket after the shed frame, so they surface as
    /// `Disconnected` at EOF — never silently swallowed as busy).
    shed: Arc<AtomicU32>,
    next_id: AtomicU64,
    reader: Option<JoinHandle<()>>,
}

impl Client {
    /// Connect to `addr` (anything [`std::net::ToSocketAddrs`] accepts,
    /// e.g. `"127.0.0.1:7380"`).
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        Client::connect_with(addr, DEFAULT_MAX_FRAME_BYTES)
    }

    /// [`Client::connect`] with an explicit frame-size cap, for talking
    /// to servers configured with a non-default
    /// [`super::ServeConfig::max_frame_bytes`].
    pub fn connect_with(addr: &str, max_frame_bytes: u32) -> anyhow::Result<Client> {
        let stream =
            TcpStream::connect(addr).map_err(|e| anyhow!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let writer = stream
            .try_clone()
            .map_err(|e| anyhow!("clone stream: {e}"))?;
        let read_half = stream
            .try_clone()
            .map_err(|e| anyhow!("clone stream: {e}"))?;
        let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
        let dead = Arc::new(AtomicBool::new(false));
        let shed = Arc::new(AtomicU32::new(0));
        let reader_map = Arc::clone(&pending);
        let reader_dead = Arc::clone(&dead);
        let reader_shed = Arc::clone(&shed);
        let reader = std::thread::Builder::new()
            .name("hadacore-client-reader".to_string())
            .spawn(move || {
                reader_loop(read_half, &reader_map, &reader_dead, &reader_shed, max_frame_bytes)
            })
            .map_err(|e| anyhow!("spawn reader: {e}"))?;
        Ok(Client {
            writer: Mutex::new(writer),
            stream,
            pending,
            dead,
            shed,
            next_id: AtomicU64::new(1),
            reader: Some(reader),
        })
    }

    /// True once the reader has stopped: no further replies can arrive.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    /// The retry hint from a connection-level shed (`Busy` id 0), if
    /// the acceptor sent one. A shed connection is about to close; the
    /// caller should reconnect (or fail over) after the hint.
    pub fn shed_retry_us(&self) -> Option<u32> {
        match self.shed.load(Ordering::Acquire) {
            0 => None,
            us => Some(us),
        }
    }

    fn register(&self) -> Result<(u64, PendingReply), ClientError> {
        if let Some(retry_after_us) = self.shed_retry_us() {
            return Err(ClientError::Busy { retry_after_us });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.pending.lock().unwrap().insert(id, tx);
        // re-check *after* inserting: either this check observes the
        // dead flag, or the reader (which sets the flag before draining
        // the map) observes our entry and resolves it — no interleaving
        // leaves a waiter stranded
        if self.is_dead() {
            self.pending.lock().unwrap().remove(&id);
            return Err(ClientError::Closed { detail: "connection closed".to_string() });
        }
        Ok((id, PendingReply { id, rx }))
    }

    fn write(&self, frame: &Frame) -> Result<(), ClientError> {
        let mut w = self.writer.lock().unwrap();
        write_frame(&mut *w, frame)
            .map_err(|e| ClientError::Closed { detail: format!("write frame: {e}") })?;
        w.flush()
            .map_err(|e| ClientError::Closed { detail: format!("flush: {e}") })
    }

    /// Pipeline one request; the client overwrites `req.id` with a
    /// connection-unique id (echoed on the returned handle). Fails fast
    /// — with a typed, retriable error — once the connection is dead or
    /// was shed by the acceptor.
    pub fn submit(&self, mut req: WireRequest) -> Result<PendingReply, ClientError> {
        let (id, reply) = self.register()?;
        req.id = id;
        match self.write(&Frame::Request(req)) {
            Ok(()) => Ok(reply),
            Err(e) => {
                self.pending.lock().unwrap().remove(&id);
                Err(e)
            }
        }
    }

    /// Submit and block. Failures are the typed [`ClientError`]:
    /// `Busy` replies become [`ClientError::Busy`] carrying the
    /// server's `retry_after_us` hint (use
    /// [`ClientError::is_retriable`] to branch), error frames become
    /// [`ClientError::Server`] with their [`ErrorCode`](super::wire::ErrorCode).
    pub fn transform(&self, req: WireRequest) -> Result<WireResponse, ClientError> {
        match self.submit(req)?.wait() {
            Reply::Response(r) => Ok(r),
            Reply::Busy { retry_after_us } => Err(ClientError::Busy { retry_after_us }),
            Reply::Error { code, msg } => Err(ClientError::Server { code, msg }),
            Reply::Disconnected => {
                Err(ClientError::Closed { detail: "connection closed".to_string() })
            }
            other => Err(ClientError::Unexpected { detail: format!("{other:?}") }),
        }
    }

    /// Round-trip a ping; returns the measured latency.
    pub fn ping(&self) -> anyhow::Result<Duration> {
        let (id, reply) = self.register()?;
        let t0 = Instant::now();
        self.write(&Frame::Ping { id })?;
        match reply.wait() {
            Reply::Pong => Ok(t0.elapsed()),
            other => Err(anyhow!("unexpected ping reply {other:?}")),
        }
    }

    /// Fetch the server's metrics snapshot.
    pub fn stats(&self) -> anyhow::Result<WireStats> {
        let (id, reply) = self.register()?;
        self.write(&Frame::StatsRequest { id })?;
        match reply.wait() {
            Reply::Stats(s) => Ok(s),
            other => Err(anyhow!("unexpected stats reply {other:?}")),
        }
    }

    /// Fetch the server's metrics registry as Prometheus-style text
    /// exposition — the same bytes its HTTP `/metrics` endpoint serves.
    pub fn stats_text(&self) -> anyhow::Result<String> {
        let (id, reply) = self.register()?;
        self.write(&Frame::StatsTextRequest { id })?;
        match reply.wait() {
            Reply::StatsText(text) => Ok(text),
            other => Err(anyhow!("unexpected stats-text reply {other:?}")),
        }
    }

    /// Drain the server's flight-recorder rings: events for `trace`
    /// only, or every buffered event when `trace` is 0. Draining is
    /// destructive server-side (the rings empty as they are read).
    pub fn trace_dump(&self, trace: u64) -> anyhow::Result<Vec<crate::obs::SpanEvent>> {
        let (id, reply) = self.register()?;
        self.write(&Frame::TraceRequest { id, trace })?;
        match reply.wait() {
            Reply::TraceDump(events) => Ok(events),
            other => Err(anyhow!("unexpected trace reply {other:?}")),
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        // closing both halves unblocks the reader; pending waiters get
        // `Disconnected` as the reader drains out
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Mark the connection dead *before* draining, so a concurrent
/// `register()` either sees the flag or gets drained here — no
/// interleaving leaves a waiter stranded. Every waiter gets `reply`.
fn fail_all(pending: &PendingMap, dead: &Arc<AtomicBool>, reply: &Reply) {
    dead.store(true, Ordering::Release);
    let mut map = pending.lock().unwrap();
    for (_, tx) in map.drain() {
        let _ = tx.send(reply.clone());
    }
}

fn reader_loop(
    mut stream: TcpStream,
    pending: &PendingMap,
    dead: &Arc<AtomicBool>,
    shed: &Arc<AtomicU32>,
    max_frame_bytes: u32,
) {
    // Incremental framing, mirroring the server's connection reader: one
    // retained accumulator instead of a fresh length-sized Vec per frame
    // (`read_frame`) keeps the client reader allocation-quiet once its
    // buffer has grown to the connection's largest frame.
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    loop {
        loop {
            let frame = match decode_frame(&buf, max_frame_bytes) {
                Ok(Some((frame, used))) => {
                    buf.drain(..used);
                    frame
                }
                Ok(None) => break, // need more bytes
                Err(_) => {
                    // corrupt length-prefixed stream: cannot resync
                    fail_all(pending, dead, &Reply::Disconnected);
                    return;
                }
            };
            let id = frame.id();
            let reply = match frame {
                Frame::Response(r) => Reply::Response(r),
                // id 0 is never assigned by a client: a Busy carrying
                // it is the acceptor's *connection-level* shed (the
                // handler pool is full and the server is closing this
                // socket). Record the hint so new submits fail fast
                // with a typed retriable Busy — but do NOT fail the
                // in-flight waiters: their requests were accepted (or
                // not) independently of this connection's admission,
                // and the EOF that follows the shed frame resolves
                // whatever is still pending as `Disconnected`, which
                // is the honest outcome for a request the server never
                // answered.
                Frame::Busy { id: 0, retry_after_us } => {
                    shed.store(retry_after_us.max(1), Ordering::Release);
                    continue;
                }
                Frame::Busy { retry_after_us, .. } => Reply::Busy { retry_after_us },
                Frame::Error(e) => Reply::Error { code: e.code, msg: e.msg },
                Frame::Pong { .. } => Reply::Pong,
                Frame::Stats(s) => Reply::Stats(s),
                Frame::StatsText { text, .. } => Reply::StatsText(text),
                Frame::TraceDump { events, .. } => Reply::TraceDump(events),
                // a server never sends these; drop silently
                Frame::Request(_)
                | Frame::Ping { .. }
                | Frame::StatsRequest { .. }
                | Frame::StatsTextRequest { .. }
                | Frame::TraceRequest { .. } => continue,
            };
            if let Some(tx) = pending.lock().unwrap().remove(&id) {
                let _ = tx.send(reply);
            }
            // replies whose waiter already went away are dropped
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                // EOF: the server is done with us
                fail_all(pending, dead, &Reply::Disconnected);
                return;
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                // reset or hard error
                fail_all(pending, dead, &Reply::Disconnected);
                return;
            }
        }
    }
}
