//! # HadaCore — matrix-unit-accelerated Fast Walsh-Hadamard Transform
//!
//! Reproduction of *HadaCore: Tensor Core Accelerated Hadamard Transform
//! Kernel* (Agarwal, Astra, Hoque, Srivatsa, Ganti, Wright, Chen; 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1** (`python/compile/kernels/`): the transform as a Pallas
//!   kernel whose rounds are 16x16 matmuls (MXU-shaped), AOT-lowered to HLO
//!   text.
//! * **Layer 2** (`python/compile/model.py`): QuaRot-style quantised
//!   attention / transformer blocks that call the kernel, lowered the same
//!   way.
//! * **Layer 3** (this crate): the serving coordinator — artifact registry,
//!   request router, dynamic batcher, PJRT runtime — plus the natively
//!   implemented transform substrate, the quantisation substrate, and the
//!   analytical GPU model that regenerates every table/figure of the
//!   paper's evaluation.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`hadamard`] | native FWHT kernels: scalar oracle, Dao-style baseline, HadaCore 16x16-block algorithm, f16/bf16 |
//! | [`quant`] | FP8/INT8/INT4 simulated quantisation + error metrics |
//! | [`gpu_model`] | analytical A100/H100 simulator for the paper's evaluation grids |
//! | [`runtime`] | PJRT wrapper: load AOT HLO-text artifacts, compile, execute |
//! | [`coordinator`] | request router, bucketed dynamic batcher, metrics, server loop |
//! | [`harness`] | workload generation + table/figure regeneration |
//! | [`util`] | std-only support: JSON, f16/bf16 bits, PRNG, CLI, micro-bench, mini-proptest |
//!
//! ## Quickstart
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath; see examples/quickstart.rs
//! // for the executed version of this snippet)
//! use hadacore::hadamard::{fwht_hadacore_f32, FwhtOptions};
//!
//! let n = 1024;
//! let mut data = vec![1.0f32; 4 * n];
//! fwht_hadacore_f32(&mut data, n, &FwhtOptions::normalized(n));
//! ```

pub mod coordinator;
pub mod gpu_model;
pub mod hadamard;
pub mod harness;
pub mod quant;
pub mod runtime;
pub mod util;

pub use hadamard::{fwht_dao_f32, fwht_hadacore_f32, fwht_scalar_f32, FwhtOptions};

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Maximum supported Hadamard size, `2^15` — same ceiling as the paper.
pub const MAX_HADAMARD_SIZE: usize = 1 << 15;
