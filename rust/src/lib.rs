//! # HadaCore — matrix-unit-accelerated Fast Walsh-Hadamard Transform
//!
//! Reproduction of *HadaCore: Tensor Core Accelerated Hadamard Transform
//! Kernel* (Agarwal, Astra, Hoque, Srivatsa, Ganti, Wright, Chen; 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1** (`python/compile/kernels/`): the transform as a Pallas
//!   kernel whose rounds are 16x16 matmuls (MXU-shaped), AOT-lowered to HLO
//!   text.
//! * **Layer 2** (`python/compile/model.py`): QuaRot-style quantised
//!   attention / transformer blocks that call the kernel, lowered the same
//!   way.
//! * **Layer 3** (this crate): the serving coordinator — artifact registry,
//!   request router, dynamic batcher, PJRT runtime — plus the natively
//!   implemented transform substrate, the quantisation substrate, and the
//!   analytical GPU model that regenerates every table/figure of the
//!   paper's evaluation.
//!
//! ## Module map
//!
//! The full walkthrough (layering, threading model, data flow) lives in
//! `docs/ARCHITECTURE.md` at the repository root; the repo-level
//! `README.md` has quickstart commands.
//!
//! | module | role |
//! |---|---|
//! | [`hadamard`] | native FWHT kernels: scalar oracle, Dao-style baseline, HadaCore 16x16-block algorithm, f16/bf16; sizes `B * 2^k`, `B ∈ {1,12,20,28,40}` (see `docs/KERNEL_MATH.md`) |
//! | [`exec`] | batched execution engine: worker pool, per-thread workspaces, plan cache |
//! | [`quant`] | FP8/INT8/INT4 simulated quantisation + error metrics |
//! | [`gpu_model`] | analytical A100/H100 simulator for the paper's evaluation grids |
//! | [`runtime`] | PJRT wrapper: load AOT HLO-text artifacts, compile, execute |
//! | [`coordinator`] | request router, bucketed dynamic batcher, metrics, server loop |
//! | [`serve`] | TCP serving layer: wire protocol, bounded-handler server with load shedding, pipelining client, open-loop load generator |
//! | [`obs`] | unified observability: process-wide metrics registry with text exposition, request-scoped span tracing, per-thread flight-recorder rings |
//! | [`harness`] | workload generation + table/figure regeneration |
//! | [`util`] | std-only support: JSON, f16/bf16 bits, PRNG, CLI, micro-bench, mini-proptest, mini-anyhow |
//!
//! ## Quickstart
//!
//! ```
//! use hadacore::exec::ExecEngine;
//! use hadacore::hadamard::{fwht_hadacore_f32, FwhtOptions, KernelKind};
//!
//! let n = 1024;
//! // one-shot kernel call
//! let mut data = vec![1.0f32; 4 * n];
//! fwht_hadacore_f32(&mut data, n, &FwhtOptions::normalized(n));
//!
//! // batched, multi-threaded engine (see examples/quickstart.rs)
//! let engine = ExecEngine::default();
//! let mut batch = vec![1.0f32; 64 * n];
//! engine.run(KernelKind::HadaCore, &mut batch, n, &FwhtOptions::normalized(n));
//! ```

pub mod coordinator;
pub mod exec;
pub mod gpu_model;
pub mod hadamard;
pub mod harness;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

pub use exec::{ExecConfig, ExecEngine};
pub use hadamard::{fwht_dao_f32, fwht_hadacore_f32, fwht_scalar_f32, FwhtOptions};

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Maximum supported Hadamard size, `2^16`. The paper's own evaluation
/// grid stops at `2^15`, but the `B * 2^k` size family (see
/// [`hadamard::split_base`]) admits Llama-family hidden dims above it —
/// 40960 = 40·2^10 in particular — so the ceiling sits one doubling
/// higher.
pub const MAX_HADAMARD_SIZE: usize = 1 << 16;
