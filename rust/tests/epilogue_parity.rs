//! Integration: the fused rotate→quantize epilogue must be bit-identical
//! to the unfused two-pass reference — across kernels
//! (scalar/dao/hadacore), dtypes (f32/f16/bf16), the paper's size axis
//! (256..8192) plus non-power-of-two `B * 2^k` sizes, chunk boundaries,
//! and lane counts (1, 4, 8).
//!
//! The unfused reference for [`hadacore::quant::Epilogue::QuantFp8`] is
//! the engine transform followed by `fp8_quantize_slice` over the whole
//! (widened, for 16-bit storage) buffer; for
//! [`hadacore::quant::Epilogue::QuantInt8`] it is the transform followed
//! by `int_quantize_grouped`. Both the quantised data and the returned
//! scale(s) must match exactly: the per-tensor amax is reduced per chunk
//! through a shared accumulator, and `max` over finite nonnegative
//! values is exact under any association, so sharding must not change a
//! single bit.

use hadacore::exec::{ExecConfig, ExecEngine, ExecElement, TunePolicy};
use hadacore::hadamard::{FwhtOptions, KernelKind};
use hadacore::quant::{
    fp8_quantize_slice, int_quantize_grouped, Epilogue, Fp8Format, IntBits,
    QuantScales,
};
use hadacore::util::f16::{Element, BF16, F16};
use hadacore::util::rng::Rng;

/// Lane configurations under test: no pool, a typical pool, a
/// deliberately aggressive sharder (tiny chunks => many chunk
/// boundaries, so the two-phase reduction crosses many workers), and
/// pinned round-fusion depths — the fused-rounds + fused-epilogue
/// combination must stay bit-identical to the unfused reference too.
fn engines() -> Vec<(&'static str, ExecEngine)> {
    vec![
        ("t1", ExecEngine::single_threaded()),
        (
            "t4",
            ExecEngine::new(ExecConfig {
                threads: 4,
                chunks_per_thread: 2,
                min_chunk_elems: 2048,
                ..ExecConfig::default()
            }),
        ),
        (
            "t8-fine",
            ExecEngine::new(ExecConfig {
                threads: 8,
                chunks_per_thread: 4,
                min_chunk_elems: 256,
                ..ExecConfig::default()
            }),
        ),
        (
            "t4-d2",
            ExecEngine::new(ExecConfig {
                threads: 4,
                chunks_per_thread: 2,
                min_chunk_elems: 512,
                tune: TunePolicy::FixedDepth(2),
            }),
        ),
        (
            "t4-d3",
            ExecEngine::new(ExecConfig {
                threads: 4,
                chunks_per_thread: 2,
                min_chunk_elems: 512,
                tune: TunePolicy::FixedDepth(3),
            }),
        ),
    ]
}

/// (n, rows) grid: the acceptance sizes with row counts chosen to not
/// divide evenly into chunks, plus a single-row batch, plus
/// non-power-of-two `B * 2^k` sizes (group scales must divide them too).
const SHAPES: [(usize, usize); 7] =
    [(256, 67), (512, 1), (768, 13), (1024, 13), (4096, 9), (8192, 3), (14336, 3)];

fn check_fp8<E>(
    label: &str,
    engine: &ExecEngine,
    kind: KernelKind,
    base: &[E],
    n: usize,
    fmt: Fp8Format,
) where
    E: ExecElement + PartialEq + std::fmt::Debug,
{
    let opts = FwhtOptions::normalized(n);

    // unfused two-pass reference: transform, widen, quantize, narrow
    let mut unfused: Vec<E> = base.to_vec();
    engine.run(kind, &mut unfused, n, &opts);
    let mut widened: Vec<f32> = unfused.iter().map(|v| v.to_f32()).collect();
    let want_scale = fp8_quantize_slice(&mut widened, fmt);
    let want: Vec<E> = widened.iter().map(|&v| E::from_f32(v)).collect();

    // fused: one engine call, quantised in the same chunk traversal
    let mut fused: Vec<E> = base.to_vec();
    let scales = engine.run_with_epilogue(
        kind,
        &mut fused,
        n,
        &opts,
        Epilogue::QuantFp8 { fmt },
    );
    assert_eq!(
        scales,
        QuantScales::PerTensor(want_scale),
        "{label}: scale mismatch"
    );
    assert_eq!(want, fused, "{label}: fused fp8 output diverged");
}

#[test]
fn fused_fp8_bit_identical_across_kernels_dtypes_sizes_lanes() {
    let mut rng = Rng::new(0xE41);
    for (ename, engine) in engines() {
        for (n, rows) in SHAPES {
            let x = rng.normal_vec(rows * n);
            for kind in KernelKind::all() {
                let label = format!("{ename} {kind:?} {rows}x{n}");
                check_fp8(
                    &format!("{label} f32"),
                    &engine,
                    kind,
                    &x,
                    n,
                    Fp8Format::E4M3,
                );
                let f16: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
                check_fp8(
                    &format!("{label} f16"),
                    &engine,
                    kind,
                    &f16,
                    n,
                    Fp8Format::E4M3,
                );
                let bf16: Vec<BF16> =
                    x.iter().map(|&v| BF16::from_f32(v)).collect();
                check_fp8(
                    &format!("{label} bf16"),
                    &engine,
                    kind,
                    &bf16,
                    n,
                    Fp8Format::E5M2,
                );
            }
        }
    }
}

#[test]
fn fused_int8_grouped_bit_identical_across_engines() {
    let mut rng = Rng::new(0x138);
    for (ename, engine) in engines() {
        for (n, rows) in SHAPES {
            let x = rng.normal_vec(rows * n);
            for group in [32usize, n] {
                let opts = FwhtOptions::normalized(n);
                let mut unfused = x.clone();
                engine.run_f32(KernelKind::HadaCore, &mut unfused, n, &opts);
                let want_scales =
                    int_quantize_grouped(&mut unfused, group, IntBits::Int8);

                let mut fused = x.clone();
                let scales = engine.run_with_epilogue(
                    KernelKind::HadaCore,
                    &mut fused,
                    n,
                    &opts,
                    Epilogue::QuantInt8 { group },
                );
                let label = format!("{ename} {rows}x{n} group={group}");
                assert_eq!(scales, QuantScales::PerGroup(want_scales), "{label}");
                assert_eq!(unfused, fused, "{label}: fused int8 output diverged");
            }
        }
    }
}

#[test]
fn fused_fp8_handles_outlier_heavy_payloads() {
    // heavy-tailed payloads (the activation regime rotations target)
    // stress the amax reduction: the max lives in one chunk while the
    // others are orders of magnitude smaller
    let mut rng = Rng::new(0x0E7);
    let engine = ExecEngine::new(ExecConfig {
        threads: 8,
        chunks_per_thread: 4,
        min_chunk_elems: 256,
        ..ExecConfig::default()
    });
    let (rows, n) = (29usize, 1024usize);
    let mut x = rng.normal_vec(rows * n);
    x[17 * n + 5] = 4.0e4; // single extreme outlier deep in one chunk
    for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
        check_fp8("outliers f32", &engine, KernelKind::HadaCore, &x, n, fmt);
    }
}

#[test]
fn fused_epilogue_scale_has_the_documented_meaning() {
    // the returned per-tensor scale must be exactly amax / max_finite of
    // the *rotated* (pre-quantisation) tensor, and quantised magnitudes
    // must stay bounded by amax (the fn-saturation convention)
    let mut rng = Rng::new(0xDE);
    let engine = ExecEngine::default();
    let (rows, n) = (8usize, 2048usize);
    let orig = rng.normal_vec(rows * n);
    let opts = FwhtOptions::normalized(n);

    let mut rotated = orig.clone();
    engine.run_f32(KernelKind::HadaCore, &mut rotated, n, &opts);
    let amax = rotated.iter().fold(0.0f32, |m, v| m.max(v.abs()));

    let mut data = orig;
    let scales = engine.run_with_epilogue(
        KernelKind::HadaCore,
        &mut data,
        n,
        &opts,
        Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
    );
    let scale = scales.per_tensor().expect("per-tensor scale");
    assert_eq!(scale, amax / Fp8Format::E4M3.max_finite());
    let qmax = data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    assert!(
        qmax <= amax * (1.0 + 1e-6),
        "quantised magnitude {qmax} exceeds amax {amax}"
    );
}
