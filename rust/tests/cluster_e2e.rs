//! End-to-end tests of the cluster tier (`serve/cluster.rs`) — the
//! `cluster-e2e` CI gate.
//!
//! Acceptance contract (ISSUE 9):
//!
//! * a 3-backend fleet behind the routing proxy answers **byte-identical**
//!   to direct `Coordinator::submit` across sizes × dtypes × epilogues ×
//!   prologues;
//! * routing is homogeneous: while the fleet is healthy, no two shards
//!   ever see the same `(n, dtype, epilogue, prologue)` bucket;
//! * killing a backend mid-traffic loses zero requests — in-flight work
//!   fails over (exercised non-vacuously: the proxy's retry counter must
//!   move) and the restarted backend rejoins the fleet;
//! * draining a backend under load moves new traffic off it without a
//!   dropped request, and undraining hands its keys back.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hadacore::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, TransformRequest,
};
use hadacore::hadamard::{KernelKind, Prologue};
use hadacore::quant::Epilogue;
use hadacore::serve::wire::{decode_elems, encode_elems, WireRequest, WireResponse};
use hadacore::serve::{
    cluster, serve, supervise, Client, ClusterConfig, ClusterHandle, ServeConfig,
    ServeHandle,
};
use hadacore::util::f16::DType;
use hadacore::util::rng::Rng;

fn start_coordinator(workers: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(
            None,
            CoordinatorConfig {
                workers,
                batcher: BatcherConfig {
                    max_delay: Duration::from_micros(200),
                    work_conserving: true,
                },
                idle_timeout: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap(),
    )
}

/// One fleet shard: its coordinator and TCP front-end. The pipelining
/// cap is raised well past the defaults because the proxy multiplexes
/// every downstream client over a single upstream connection.
fn start_backend() -> (Arc<Coordinator>, ServeHandle) {
    let coord = start_coordinator(2);
    let handle = serve(
        Arc::clone(&coord),
        ServeConfig {
            pipeline_depth: 256,
            max_inflight: 1024,
            poll_interval: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap();
    (coord, handle)
}

struct Fleet {
    /// `None` where a backend was taken (killed) by a test.
    backends: Vec<Option<(Arc<Coordinator>, ServeHandle)>>,
    proxy: ClusterHandle,
    /// Reference coordinator for byte-identity: the transform is a pure
    /// deterministic function, so a fourth, independent coordinator must
    /// agree bit-for-bit with whatever shard served the request.
    reference: Arc<Coordinator>,
}

fn start_fleet(n: usize) -> Fleet {
    let backends: Vec<_> = (0..n).map(|_| start_backend()).collect();
    let proxy = cluster(ClusterConfig {
        backends: backends.iter().map(|(_, h)| h.addr().to_string()).collect(),
        health_interval: Duration::from_millis(25),
        poll_interval: Duration::from_millis(10),
        ..Default::default()
    })
    .unwrap();
    for i in 0..n {
        assert!(proxy.backend(i).healthy, "backend {i} must probe healthy at start");
    }
    Fleet {
        backends: backends.into_iter().map(Some).collect(),
        proxy,
        reference: start_coordinator(2),
    }
}

impl Fleet {
    fn teardown(self) {
        drop(self.proxy);
        for (coord, handle) in self.backends.into_iter().flatten() {
            handle.shutdown();
            coord.drain();
        }
        self.reference.drain();
    }
}

/// One request shape = one routing key.
#[derive(Clone)]
struct Case {
    n: usize,
    rows: usize,
    kernel: KernelKind,
    dtype: DType,
    epilogue: Epilogue,
    prologue: Prologue,
    seed: u64,
}

fn case_grid() -> Vec<Case> {
    let mut cases = Vec::new();
    let mut seed = 0x0C10_5EED;
    for &n in &[256usize, 512, 1024, 2048, 4096, 14336] {
        for (epilogue, prologue) in [
            (Epilogue::None, Prologue::None),
            (Epilogue::QuantInt8 { group: 64 }, Prologue::None),
            (Epilogue::None, Prologue::SignFlip { seed: 0x5EED_0909 }),
        ] {
            seed += 1;
            cases.push(Case {
                n,
                rows: 1 + (seed as usize % 3),
                kernel: KernelKind::HadaCore,
                dtype: DType::F32,
                epilogue,
                prologue,
                seed,
            });
        }
    }
    for &dtype in &[DType::F16, DType::BF16] {
        seed += 1;
        cases.push(Case {
            n: 1024,
            rows: 2,
            kernel: KernelKind::HadaCore,
            dtype,
            epilogue: Epilogue::None,
            prologue: Prologue::None,
            seed,
        });
    }
    cases
}

/// The canonical f32 payload a case's wire bytes decode to server-side.
fn canonical_payload(case: &Case) -> Vec<f32> {
    let mut rng = Rng::new(case.seed);
    let raw = rng.normal_vec(case.rows * case.n);
    decode_elems(&encode_elems(&raw, case.dtype), case.dtype).unwrap()
}

fn wire_request(case: &Case) -> WireRequest {
    let data = canonical_payload(case);
    let mut wire = WireRequest::from_f32(0, case.n, &data, case.kernel, case.dtype);
    wire.epilogue = case.epilogue;
    wire.prologue = case.prologue;
    wire
}

/// Byte-identity oracle: direct submit of the identical canonical
/// payload on the reference coordinator.
fn assert_identical(reference: &Coordinator, case: &Case, resp: &WireResponse) {
    let mut req = TransformRequest::new(1, case.n, canonical_payload(case));
    req.kernel = case.kernel;
    req.epilogue = case.epilogue;
    req.prologue = case.prologue;
    let direct = reference.transform(req).unwrap();
    assert_eq!(
        resp.payload,
        encode_elems(&direct.data, case.dtype),
        "case n={} {:?} {:?} {:?}: proxied bytes must be bit-identical \
         to direct submit",
        case.n,
        case.dtype,
        case.epilogue,
        case.prologue,
    );
    assert_eq!(resp.scales, direct.scales, "case n={}: scales must match", case.n);
    assert_eq!(resp.n as usize, case.n);
    assert_eq!(resp.rows as usize, case.rows);
}

/// Drive one request to completion through the proxy, retrying the
/// retriable outcomes (`Busy`, a dead proxy connection never happens in
/// these tests) — the loop every real cluster client runs.
fn transform_retrying(client: &Client, req: &WireRequest) -> WireResponse {
    for _ in 0..100 {
        match client.transform(req.clone()) {
            Ok(r) => return r,
            Err(e) if e.is_retriable() => {
                let us = u64::from(e.retry_after_us().unwrap_or(500));
                std::thread::sleep(Duration::from_micros(us.min(5_000)));
            }
            Err(e) => panic!("non-retriable cluster error: {e}"),
        }
    }
    panic!("request did not complete in 100 attempts");
}

/// Which shard owns `case`'s routing key right now: send one probe
/// request and watch whose forwarded counter moves.
fn owner_of(fleet: &Fleet, client: &Client, case: &Case) -> usize {
    let before: Vec<u64> =
        (0..fleet.proxy.backend_count()).map(|i| fleet.proxy.backend(i).forwarded).collect();
    let resp = transform_retrying(client, &wire_request(case));
    assert_identical(&fleet.reference, case, &resp);
    for i in 0..fleet.proxy.backend_count() {
        if fleet.proxy.backend(i).forwarded > before[i] {
            return i;
        }
    }
    panic!("no backend's forwarded counter moved");
}

#[test]
fn fleet_is_byte_identical_and_routing_stays_homogeneous() {
    let fleet = start_fleet(3);
    let addr = fleet.proxy.addr().to_string();
    let cases = case_grid();
    assert!(cases.len() >= 18, "grid must stay meaningful");

    // two concurrent pipelining clients, each sending the whole grid
    // twice — so every key arrives repeatedly, from both connections
    let mut threads = Vec::new();
    for _ in 0..2 {
        let addr = addr.clone();
        let cases = cases.clone();
        let reference = Arc::clone(&fleet.reference);
        threads.push(std::thread::spawn(move || {
            let client = Client::connect(&addr).unwrap();
            for _ in 0..2 {
                let pending: Vec<_> = cases
                    .iter()
                    .map(|c| client.submit(wire_request(c)).unwrap())
                    .collect();
                for (case, p) in cases.iter().zip(pending) {
                    match p.wait() {
                        hadacore::serve::Reply::Response(r) => {
                            assert_identical(&reference, case, &r)
                        }
                        other => panic!("case n={}: unexpected reply {other:?}", case.n),
                    }
                }
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }

    // nothing failed over (the fleet was healthy throughout), so the
    // route-key bookkeeping is exactly the rendezvous map...
    assert_eq!(fleet.proxy.counters().retries.load(Ordering::Relaxed), 0);

    // ...and it must be homogeneous: no key on two shards, every shard
    // sharing the work (the grid is far larger than the fleet)
    let keysets: Vec<Vec<hadacore::serve::RouteKey>> =
        (0..3).map(|i| fleet.proxy.route_keys(i)).collect();
    for i in 0..3 {
        for j in (i + 1)..3 {
            for k in &keysets[i] {
                assert!(
                    !keysets[j].contains(k),
                    "key {k:?} routed to both shard {i} and shard {j}"
                );
            }
        }
        assert!(
            !keysets[i].is_empty(),
            "shard {i} must own some keys of a {}-key grid",
            cases.len()
        );
    }
    let total: usize = keysets.iter().map(Vec::len).sum();
    assert!(total >= cases.len(), "every distinct key must be accounted for");

    fleet.teardown();
}

#[test]
fn killed_backend_fails_over_with_zero_lost_requests_and_rejoins() {
    let mut fleet = start_fleet(3);
    let client = Client::connect(&fleet.proxy.addr().to_string()).unwrap();

    // a deliberately slow case (large scalar batch, native-forced) so the
    // victim shard still has requests queued or executing when it dies
    let slow = Case {
        n: 32768,
        rows: 8,
        kernel: KernelKind::Scalar,
        dtype: DType::F32,
        epilogue: Epilogue::None,
        prologue: Prologue::None,
        seed: 0xDEAD,
    };
    let victim = owner_of(&fleet, &client, &slow);

    // pipeline a burst of slow requests at the victim's key, then kill
    // the victim while they are still being served
    let mut slow_wire = wire_request(&slow);
    slow_wire.force_native = true;
    let pending: Vec<_> =
        (0..8).map(|_| client.submit(slow_wire.clone()).unwrap()).collect();
    std::thread::sleep(Duration::from_millis(5));
    // kill = full backend teardown, exactly what a crashed process
    // looks like from the proxy's side of the sockets
    let (coord, handle) = fleet.backends[victim].take().unwrap();
    handle.shutdown();
    coord.drain();

    // zero lost: every pipelined request resolves as a Response — the
    // in-flight ones through failover, never an error or a hang
    let deadline = Instant::now() + Duration::from_secs(60);
    for p in pending {
        let resp = loop {
            match p.try_wait() {
                Some(hadacore::serve::Reply::Response(r)) => break Some(r),
                Some(hadacore::serve::Reply::Busy { .. }) => break None,
                Some(other) => panic!("lost a request to {other:?}"),
                None => {
                    assert!(Instant::now() < deadline, "a request hung — lost");
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        };
        match resp {
            Some(r) => assert_identical(&fleet.reference, &slow, &r),
            // an attempt-budget Busy is retriable by contract; drive the
            // retry to completion — still nothing lost
            None => {
                let r = transform_retrying(&client, &slow_wire);
                assert_identical(&fleet.reference, &slow, &r);
            }
        }
    }
    // ...and the failover was exercised non-vacuously
    let retries = fleet.proxy.counters().retries.load(Ordering::Relaxed);
    assert!(retries > 0, "killing a loaded backend must force failover retries");

    // follow-up traffic on the dead shard's key keeps working (routed
    // around the corpse), and the fleet of two still covers the grid
    for case in case_grid().iter().take(6) {
        let r = transform_retrying(&client, &wire_request(case));
        assert_identical(&fleet.reference, case, &r);
    }

    // restart: a fresh backend on a fresh port takes the dead slot and
    // the proxy re-probes it back into the routing set
    let (new_coord, new_handle) = start_backend();
    fleet.proxy.replace_backend(victim, &new_handle.addr().to_string());
    let t0 = Instant::now();
    while !fleet.proxy.backend(victim).healthy {
        assert!(t0.elapsed() < Duration::from_secs(5), "restart must re-probe healthy");
        std::thread::sleep(Duration::from_millis(5));
    }
    // the restarted shard owns its rendezvous keys again: the slow key
    // routes straight back to the same slot
    let before = fleet.proxy.backend(victim).forwarded;
    let r = transform_retrying(&client, &wire_request(&slow));
    assert_identical(&fleet.reference, &slow, &r);
    assert!(
        fleet.proxy.backend(victim).forwarded > before,
        "the restarted backend must win its keys back"
    );

    drop(client);
    new_handle.shutdown();
    new_coord.drain();
    fleet.teardown();
}

#[test]
fn drain_moves_new_traffic_off_a_backend_without_dropping_any() {
    let fleet = start_fleet(3);
    let client = Client::connect(&fleet.proxy.addr().to_string()).unwrap();

    let case = Case {
        n: 1024,
        rows: 2,
        kernel: KernelKind::HadaCore,
        dtype: DType::F32,
        epilogue: Epilogue::None,
        prologue: Prologue::None,
        seed: 0xD4A1,
    };
    let owner = owner_of(&fleet, &client, &case);

    // load the owner, then drain it while its queue is non-empty
    let pending: Vec<_> =
        (0..8).map(|_| client.submit(wire_request(&case)).unwrap()).collect();
    fleet.proxy.drain_backend(owner);
    // in-flight work completes normally — drain is not a kill
    for p in pending {
        match p.wait() {
            hadacore::serve::Reply::Response(r) => {
                assert_identical(&fleet.reference, &case, &r)
            }
            other => panic!("drain dropped a request: {other:?}"),
        }
    }

    // new traffic on the drained shard's key re-routes — served fine,
    // by someone else
    let drained_forwarded = fleet.proxy.backend(owner).forwarded;
    for _ in 0..5 {
        let r = transform_retrying(&client, &wire_request(&case));
        assert_identical(&fleet.reference, &case, &r);
    }
    assert_eq!(
        fleet.proxy.backend(owner).forwarded,
        drained_forwarded,
        "a draining backend must receive no new traffic"
    );
    assert!(fleet.proxy.backend(owner).draining);

    // undrain: the shard wins its rendezvous keys straight back
    fleet.proxy.undrain_backend(owner);
    let before = fleet.proxy.backend(owner).forwarded;
    let r = transform_retrying(&client, &wire_request(&case));
    assert_identical(&fleet.reference, &case, &r);
    assert!(
        fleet.proxy.backend(owner).forwarded > before,
        "an undrained backend must rejoin the routing set"
    );

    drop(client);
    fleet.teardown();
}

#[test]
fn supervisor_respawns_a_dead_backend_which_re_serves_its_old_keys() {
    use std::sync::atomic::AtomicBool;
    use std::sync::Mutex;

    // built by hand (not `start_fleet`) because the supervisor API
    // shares the proxy handle: `supervise` takes an `Arc<ClusterHandle>`
    let mut backends: Vec<Option<(Arc<Coordinator>, ServeHandle)>> =
        (0..3).map(|_| Some(start_backend())).collect();
    let proxy = Arc::new(
        cluster(ClusterConfig {
            backends: backends
                .iter()
                .map(|b| b.as_ref().unwrap().1.addr().to_string())
                .collect(),
            health_interval: Duration::from_millis(25),
            poll_interval: Duration::from_millis(10),
            ..Default::default()
        })
        .unwrap(),
    );
    let reference = start_coordinator(2);
    let client = Client::connect(&proxy.addr().to_string()).unwrap();

    let case = Case {
        n: 2048,
        rows: 2,
        kernel: KernelKind::HadaCore,
        dtype: DType::F32,
        epilogue: Epilogue::None,
        prologue: Prologue::None,
        seed: 0x5AFE,
    };
    // whose key is it: probe once and watch the forwarded counters
    let before: Vec<u64> = (0..3).map(|i| proxy.backend(i).forwarded).collect();
    let r = transform_retrying(&client, &wire_request(&case));
    assert_identical(&reference, &case, &r);
    let victim = (0..3)
        .find(|&i| proxy.backend(i).forwarded > before[i])
        .expect("some backend must have served the probe");

    // the in-process analogues of `Child::try_wait` (a shared liveness
    // flag) and of re-spawning the child process (starting a fresh serve
    // backend, parked in `replacements` for teardown)
    let dead = Arc::new(AtomicBool::new(false));
    let replacements: Arc<Mutex<Vec<(Arc<Coordinator>, ServeHandle)>>> =
        Arc::new(Mutex::new(Vec::new()));
    let alive_dead = Arc::clone(&dead);
    let respawn_dead = Arc::clone(&dead);
    let respawn_repl = Arc::clone(&replacements);
    let supervisor = supervise(
        &proxy,
        Duration::from_millis(20),
        move |i| i != victim || !alive_dead.load(Ordering::Acquire),
        move |_| {
            let (coord, handle) = start_backend();
            let addr = handle.addr().to_string();
            respawn_repl.lock().unwrap().push((coord, handle));
            respawn_dead.store(false, Ordering::Release);
            Some(addr)
        },
    )
    .unwrap();
    assert_eq!(proxy.counters().restarts.load(Ordering::Relaxed), 0);

    // kill the victim — full teardown, then raise the liveness flag the
    // supervisor polls
    let (coord, handle) = backends[victim].take().unwrap();
    handle.shutdown();
    coord.drain();
    dead.store(true, Ordering::Release);

    // the supervisor must notice, respawn, and hand the replacement back
    // to routing; the proxy re-probes it healthy
    let t0 = Instant::now();
    while proxy.counters().restarts.load(Ordering::Relaxed) == 0
        || !proxy.backend(victim).healthy
    {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "supervisor must respawn the dead backend (restarts={}, healthy={})",
            proxy.counters().restarts.load(Ordering::Relaxed),
            proxy.backend(victim).healthy,
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(proxy.counters().restarts.load(Ordering::Relaxed), 1);

    // the respawned slot re-serves its old keys: the probe case routes
    // straight back to the same index, bit-identically
    let before = proxy.backend(victim).forwarded;
    for _ in 0..3 {
        let r = transform_retrying(&client, &wire_request(&case));
        assert_identical(&reference, &case, &r);
    }
    assert!(
        proxy.backend(victim).forwarded > before,
        "the respawned backend must win its rendezvous keys back"
    );

    // no flapping: a healthy fleet is left alone
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(proxy.counters().restarts.load(Ordering::Relaxed), 1);

    drop(client);
    supervisor.shutdown();
    if let Ok(p) = Arc::try_unwrap(proxy) {
        p.shutdown();
    }
    for (coord, handle) in replacements.lock().unwrap().drain(..) {
        handle.shutdown();
        coord.drain();
    }
    for (coord, handle) in backends.into_iter().flatten() {
        handle.shutdown();
        coord.drain();
    }
    reference.drain();
}

#[test]
fn proxy_answers_ping_and_fleet_stats() {
    let fleet = start_fleet(3);
    let client = Client::connect(&fleet.proxy.addr().to_string()).unwrap();

    let case = case_grid().remove(0);
    for _ in 0..4 {
        let r = transform_retrying(&client, &wire_request(&case));
        assert_identical(&fleet.reference, &case, &r);
    }
    assert!(client.ping().unwrap() < Duration::from_secs(5));

    let stats = client.stats().unwrap();
    let get = |k: &str| {
        stats
            .counters
            .iter()
            .find(|(key, _)| key == k)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("proxy stats must carry '{k}'"))
    };
    assert_eq!(get("proxy.backends"), 3);
    assert!(get("proxy.forwarded") >= 4);
    assert!(get("proxy.responses") >= 4);
    assert_eq!(
        get("backend0.healthy") + get("backend1.healthy") + get("backend2.healthy"),
        3,
        "all shards healthy: {}",
        stats.report
    );
    let fwd: u64 =
        (0..3).map(|i| get(&format!("backend{i}.forwarded"))).sum();
    assert!(fwd >= 4, "per-backend counters must account for the traffic");
    assert!(stats.report.contains("cluster proxy"), "got: {}", stats.report);

    drop(client);
    fleet.teardown();
}
