//! Integration: the fused sign-flip rotation prologue must be
//! **bit-identical** to the unfused pre-multiply — across kernels
//! (scalar/dao/hadacore), dtypes (f32/f16/bf16), the paper's size axis
//! (256..8192) plus non-power-of-two `B · 2^k` sizes including the
//! 14336 Llama-FFN dim, chunk boundaries, lane counts, and pinned
//! round-fusion depths. This file is the named acceptance test
//! referenced from `ExecEngine::run_with_stages`.
//!
//! The unfused reference for [`Prologue::SignFlip`] is
//! [`apply_signs`] (`x ← x·D`, an explicit extra pass) followed by the
//! plain engine transform. Multiplying by ±1.0 is an exact IEEE
//! operation that commutes with the exact f16/bf16→f32 widening, so
//! fusing the flip into the chunk traversal — before or after the
//! widening copy — must not change a single output bit. For 16-bit
//! storage the reference flips the *narrow* values (also exact) to
//! prove the fused flip-on-widened placement equals it.

use hadacore::exec::{ExecConfig, ExecEngine, ExecElement, TunePolicy};
use hadacore::hadamard::{apply_signs, sign_vector, FwhtOptions, KernelKind, Prologue};
use hadacore::quant::Epilogue;
use hadacore::util::f16::{Element, BF16, F16};
use hadacore::util::rng::Rng;

/// Lane configurations under test (mirrors `epilogue_parity.rs`): no
/// pool, a typical pool, a deliberately aggressive sharder (tiny chunks
/// ⇒ many chunk boundaries, so the sign vector is applied across many
/// workers), and pinned round-fusion depths.
fn engines() -> Vec<(&'static str, ExecEngine)> {
    vec![
        ("t1", ExecEngine::single_threaded()),
        (
            "t4",
            ExecEngine::new(ExecConfig {
                threads: 4,
                chunks_per_thread: 2,
                min_chunk_elems: 2048,
                ..ExecConfig::default()
            }),
        ),
        (
            "t8-fine",
            ExecEngine::new(ExecConfig {
                threads: 8,
                chunks_per_thread: 4,
                min_chunk_elems: 256,
                ..ExecConfig::default()
            }),
        ),
        (
            "t4-d2",
            ExecEngine::new(ExecConfig {
                threads: 4,
                chunks_per_thread: 2,
                min_chunk_elems: 512,
                tune: TunePolicy::FixedDepth(2),
            }),
        ),
        (
            "t4-d3",
            ExecEngine::new(ExecConfig {
                threads: 4,
                chunks_per_thread: 2,
                min_chunk_elems: 512,
                tune: TunePolicy::FixedDepth(3),
            }),
        ),
    ]
}

/// (n, rows) grid: acceptance sizes with row counts that do not divide
/// evenly into chunks, plus a single-row batch, plus non-power-of-two
/// `B · 2^k` sizes.
const SHAPES: [(usize, usize); 7] =
    [(256, 67), (512, 1), (768, 13), (1024, 13), (4096, 9), (8192, 3), (14336, 3)];

/// Rotation seed of this suite (arbitrary; exercised against many
/// engine-drawn seeds in `proptest_invariants.rs`).
const SEED: u64 = 0x0707_5EED;

fn check_parity<E>(label: &str, engine: &ExecEngine, kind: KernelKind, base: &[E], n: usize)
where
    E: ExecElement + PartialEq + std::fmt::Debug,
{
    let opts = FwhtOptions::normalized(n);
    let signs = sign_vector(SEED, n);

    // unfused reference: flip the narrow values explicitly (exact), then
    // run the plain engine transform
    let mut unfused: Vec<E> = base
        .iter()
        .enumerate()
        .map(|(i, v)| E::from_f32(v.to_f32() * signs[i % n]))
        .collect();
    engine.run(kind, &mut unfused, n, &opts);

    // fused: one engine call, flipped inside the chunk traversal
    let mut fused: Vec<E> = base.to_vec();
    engine.run_with_stages(
        kind,
        &mut fused,
        n,
        &opts,
        Prologue::SignFlip { seed: SEED },
        Epilogue::None,
    );
    assert_eq!(unfused, fused, "{label}: fused prologue output diverged");
}

/// The named acceptance case: fused sign-flip prologue bit-identical to
/// the unfused pre-multiply, across kernels × dtypes × sizes × engine
/// shapes.
#[test]
fn fused_sign_flip_bit_identical_across_kernels_dtypes_sizes_lanes() {
    let mut rng = Rng::new(0x5107);
    for (ename, engine) in engines() {
        for (n, rows) in SHAPES {
            let x = rng.normal_vec(rows * n);
            for kind in KernelKind::all() {
                let label = format!("{ename} {kind:?} {rows}x{n}");
                check_parity(&format!("{label} f32"), &engine, kind, &x, n);
                let f16: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
                check_parity(&format!("{label} f16"), &engine, kind, &f16, n);
                let bf16: Vec<BF16> = x.iter().map(|&v| BF16::from_f32(v)).collect();
                check_parity(&format!("{label} bf16"), &engine, kind, &bf16, n);
            }
        }
    }
}

#[test]
fn f32_premultiplied_reference_via_apply_signs_matches_too() {
    // same parity stated through the library's own apply_signs helper
    // (the reference the module docs name), on the f32 path
    let mut rng = Rng::new(0x5108);
    let engine = ExecEngine::default();
    for (n, rows) in SHAPES {
        let x = rng.normal_vec(rows * n);
        let signs = sign_vector(SEED, n);
        let opts = FwhtOptions::normalized(n);

        let mut want = x.clone();
        apply_signs(&mut want, &signs);
        engine.run_f32(KernelKind::HadaCore, &mut want, n, &opts);

        let mut fused = x.clone();
        engine.run_with_stages(
            KernelKind::HadaCore,
            &mut fused,
            n,
            &opts,
            Prologue::SignFlip { seed: SEED },
            Epilogue::None,
        );
        assert_eq!(want, fused, "{rows}x{n}");
    }
}

#[test]
fn rotation_prologue_is_not_a_no_op() {
    // non-vacuity: the rotated transform must differ from the plain one
    // (a sign vector of all +1 would make every assertion above pass
    // trivially)
    let mut rng = Rng::new(0x5109);
    let engine = ExecEngine::default();
    let (rows, n) = (3usize, 1024usize);
    let x = rng.normal_vec(rows * n);
    let opts = FwhtOptions::normalized(n);
    let signs = sign_vector(SEED, n);
    assert!(signs.contains(&-1.0), "degenerate sign vector");
    assert!(signs.contains(&1.0), "degenerate sign vector");

    let mut plain = x.clone();
    engine.run_f32(KernelKind::HadaCore, &mut plain, n, &opts);
    let mut rotated = x;
    engine.run_with_stages(
        KernelKind::HadaCore,
        &mut rotated,
        n,
        &opts,
        Prologue::SignFlip { seed: SEED },
        Epilogue::None,
    );
    assert_ne!(plain, rotated, "rotation changed nothing");
}
