//! Integration: the coordinator serving through both backends, mixed
//! workloads, failure injection, and property-style checks of the
//! batching invariants under concurrency.

use std::sync::Arc;
use std::time::Duration;

use hadacore::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RouterConfig, TransformRequest,
};
use hadacore::hadamard::{fwht_scalar_f32, FwhtOptions, KernelKind};
use hadacore::harness::workload::{ServingWorkload, WorkloadConfig};
use hadacore::util::prop::assert_close;
use hadacore::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn cfg(workers: usize, delay_us: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        batcher: BatcherConfig { max_delay: Duration::from_micros(delay_us), work_conserving: false },
        router: RouterConfig::default(),
        idle_timeout: Duration::from_millis(10),
        ..Default::default()
    }
}

#[test]
fn pjrt_backend_results_match_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(Some(dir), cfg(2, 100)).unwrap();
    let mut rng = Rng::new(1);
    for n in [256usize, 1024, 4096] {
        let rows = 4;
        let x = rng.normal_vec(rows * n);

        let pjrt_resp = coord
            .transform(TransformRequest::new(1, n, x.clone()))
            .unwrap();

        let mut native_req = TransformRequest::new(2, n, x.clone());
        native_req.force_native = true;
        let native_resp = coord.transform(native_req).unwrap();
        assert_eq!(native_resp.backend, "native");

        let mut want = x;
        fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
        assert_close(&pjrt_resp.data, &want, 2e-3, 2e-3);
        assert_close(&native_resp.data, &want, 2e-3, 2e-3);
    }
    coord.shutdown();
}

#[test]
fn mixed_workload_under_concurrency() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Arc::new(Coordinator::start(Some(dir), cfg(4, 300)).unwrap());
    let total_per_thread = 100;
    let threads = 4;

    let mut joins = Vec::new();
    for t in 0..threads {
        let coord = Arc::clone(&coord);
        joins.push(std::thread::spawn(move || {
            let mut wl = ServingWorkload::new(WorkloadConfig {
                sizes: vec![128, 256, 1024, 4096],
                kernel: KernelKind::HadaCore,
                seed: t as u64,
                ..Default::default()
            });
            let mut checked = 0;
            for _ in 0..total_per_thread {
                let req = wl.next_request();
                let n = req.n;
                let input = req.data.clone();
                let resp = coord.transform(req).unwrap();
                // verify a sample of responses against the oracle
                if checked < 10 {
                    let mut want = input;
                    fwht_scalar_f32(&mut want, n, &FwhtOptions::normalized(n));
                    assert_close(&resp.data, &want, 2e-3, 2e-3);
                    checked += 1;
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.completed, (threads * total_per_thread) as u64);
    assert_eq!(snap.rejected, 0);
    assert!(snap.batches > 0);
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

#[test]
fn underfilled_pjrt_batches_fall_back_to_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // single tiny request into a 256-row bucket with a short deadline:
    // fill fraction 1/256 << min_pjrt_fill, so it must execute natively
    let coord = Coordinator::start(Some(dir), cfg(2, 50)).unwrap();
    let resp = coord
        .transform(TransformRequest::new(1, 128, vec![1.0; 128]))
        .unwrap();
    assert_eq!(resp.backend, "native");
    coord.shutdown();
}

#[test]
fn full_buckets_use_pjrt() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(Some(dir), cfg(2, 5_000)).unwrap();
    // n=32768 bucket has rows=1: a single 1-row request fills it entirely
    let mut rng = Rng::new(3);
    let resp = coord
        .transform(TransformRequest::new(1, 32768, rng.normal_vec(32768)))
        .unwrap();
    assert_eq!(resp.backend, "pjrt");
    assert_eq!(resp.batch_rows, 1);
    coord.shutdown();
}

#[test]
fn scalar_kernel_requests_route_native_and_agree() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(Some(dir), cfg(2, 100)).unwrap();
    let mut rng = Rng::new(4);
    let x = rng.normal_vec(512);
    let mut req = TransformRequest::new(1, 512, x.clone());
    req.kernel = KernelKind::Scalar; // no scalar artifacts exist
    let resp = coord.transform(req).unwrap();
    assert_eq!(resp.backend, "native");
    let mut want = x;
    fwht_scalar_f32(&mut want, 512, &FwhtOptions::normalized(512));
    assert_close(&resp.data, &want, 1e-3, 1e-3);
    coord.shutdown();
}

#[test]
fn rejection_does_not_poison_the_pipeline() {
    let coord = Coordinator::start(None, cfg(2, 100)).unwrap();
    // invalid, valid, invalid, valid...
    for i in 0..20u64 {
        if i % 2 == 0 {
            assert!(coord
                .submit(TransformRequest::new(i, 100, vec![0.0; 100]))
                .is_err());
        } else {
            let resp = coord
                .transform(TransformRequest::new(i, 64, vec![1.0; 64]))
                .unwrap();
            assert_eq!(resp.id, i);
        }
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.rejected, 10);
    assert_eq!(snap.completed, 10);
    coord.shutdown();
}

#[test]
fn throughput_scales_with_batching() {
    // sanity: open-loop load must coalesce into fewer batches than requests
    let coord = Coordinator::start(None, cfg(4, 300)).unwrap();
    let mut wl = ServingWorkload::new(WorkloadConfig {
        sizes: vec![256],
        rows_min: 1,
        rows_max: 1,
        ..Default::default()
    });
    let total = 500;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..total)
        .map(|_| coord.submit(wl.next_request()).unwrap())
        .collect();
    for h in handles {
        h.recv().unwrap().unwrap();
    }
    let elapsed = t0.elapsed();
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.completed, total as u64);
    assert!(
        snap.batches < total as u64,
        "expected coalescing: {} batches for {} requests",
        snap.batches,
        total
    );
    assert!(elapsed < Duration::from_secs(30));
    coord.shutdown();
}
