//! End-to-end tests of the observability layer (`obs/`) — the ISSUE 10
//! acceptance gate.
//!
//! Acceptance contract:
//!
//! * driving loadgen through the cluster proxy with span tracing on
//!   yields, for at least one traced request, the **full ordered span
//!   chain** proxy-admit → decode → admitted → enqueued → batch-sealed →
//!   exec-start/exec-end → framed → written, with non-decreasing
//!   timestamps (the whole fleet runs in one process, so the clock is
//!   shared and the ordering is exact);
//! * a single traced request dumped by its own id carries the same
//!   chain — the `TraceRequest`/`TraceDump` wire round trip through the
//!   proxy, which merges backend rings into its own;
//! * the `StatsText` frame exposes the unified registry through the
//!   proxy: coordinator (`hadacore_requests_total`), engine
//!   (`hadacore_exec_chunk_us`), and cluster
//!   (`hadacore_cluster_*_total`) series all render in one scrape, and
//!   the exposition parses back ([`hadacore::obs::registry`]);
//! * the HTTP `GET /metrics` listener serves the same exposition to a
//!   plain-sockets client.

use std::io::{Read, Write};
use std::sync::Arc;
use std::time::Duration;

use hadacore::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use hadacore::hadamard::KernelKind;
use hadacore::harness::workload::traffic_mix;
use hadacore::obs::registry::parse_exposition;
use hadacore::obs::trace::next_trace_id;
use hadacore::obs::{serve_metrics, SpanEvent, Stage};
use hadacore::serve::wire::WireRequest;
use hadacore::serve::{
    cluster, loadgen, serve, Client, ClusterConfig, ClusterHandle, LoadgenConfig,
    ServeConfig, ServeHandle,
};
use hadacore::util::f16::DType;
use hadacore::util::rng::Rng;

fn start_backend() -> (Arc<Coordinator>, ServeHandle) {
    let coord = Arc::new(
        Coordinator::start(
            None,
            CoordinatorConfig {
                workers: 2,
                batcher: BatcherConfig {
                    max_delay: Duration::from_micros(200),
                    work_conserving: true,
                },
                idle_timeout: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let handle = serve(
        Arc::clone(&coord),
        ServeConfig {
            pipeline_depth: 256,
            max_inflight: 1024,
            poll_interval: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap();
    (coord, handle)
}

struct Fleet {
    backends: Vec<(Arc<Coordinator>, ServeHandle)>,
    proxy: ClusterHandle,
}

fn start_fleet(n: usize) -> Fleet {
    let backends: Vec<_> = (0..n).map(|_| start_backend()).collect();
    let proxy = cluster(ClusterConfig {
        backends: backends.iter().map(|(_, h)| h.addr().to_string()).collect(),
        health_interval: Duration::from_millis(25),
        poll_interval: Duration::from_millis(10),
        ..Default::default()
    })
    .unwrap();
    Fleet { backends, proxy }
}

impl Fleet {
    fn teardown(self) {
        drop(self.proxy);
        for (coord, handle) in self.backends {
            handle.shutdown();
            coord.drain();
        }
    }
}

/// The stages every traced request must pass through, in lifecycle
/// order (exec-start/exec-end may repeat per chunk; the chain check uses
/// the first start and the last end).
const CHAIN: [Stage; 9] = [
    Stage::ProxyAdmit,
    Stage::Decode,
    Stage::Admitted,
    Stage::Enqueued,
    Stage::BatchSealed,
    Stage::ExecStart,
    Stage::ExecEnd,
    Stage::Framed,
    Stage::Written,
];

/// True when `events` (one trace's, any order) contain the full chain.
fn has_full_chain(events: &[SpanEvent]) -> bool {
    CHAIN.iter().all(|&s| events.iter().any(|e| e.stage == s))
}

/// Assert the chain's timestamps are non-decreasing in lifecycle order:
/// the first occurrence of each leading stage, the *last* exec-end (a
/// sharded batch interleaves chunk spans), then framed and written.
fn assert_ordered_chain(trace: u64, events: &[SpanEvent]) {
    let first = |s: Stage| {
        events
            .iter()
            .filter(|e| e.stage == s)
            .map(|e| e.t_us)
            .min()
            .unwrap_or_else(|| panic!("trace {trace:#x}: stage {} missing", s.name()))
    };
    let last_exec_end = events
        .iter()
        .filter(|e| e.stage == Stage::ExecEnd)
        .map(|e| e.t_us)
        .max()
        .unwrap();
    let checkpoints = [
        ("proxy-admit", first(Stage::ProxyAdmit)),
        ("decode", first(Stage::Decode)),
        ("admitted", first(Stage::Admitted)),
        ("enqueued", first(Stage::Enqueued)),
        ("batch-sealed", first(Stage::BatchSealed)),
        ("exec-start", first(Stage::ExecStart)),
        ("exec-end", last_exec_end),
        ("framed", first(Stage::Framed)),
        ("written", first(Stage::Written)),
    ];
    for pair in checkpoints.windows(2) {
        assert!(
            pair[0].1 <= pair[1].1,
            "trace {trace:#x}: {} (t={}us) must not follow {} (t={}us)",
            pair[0].0,
            pair[0].1,
            pair[1].0,
            pair[1].1,
        );
    }
}

/// Group a merged dump by trace id.
fn by_trace(events: &[SpanEvent]) -> Vec<(u64, Vec<SpanEvent>)> {
    let mut out: Vec<(u64, Vec<SpanEvent>)> = Vec::new();
    for e in events {
        match out.iter_mut().find(|(t, _)| *t == e.trace) {
            Some((_, v)) => v.push(*e),
            None => out.push((e.trace, vec![*e])),
        }
    }
    out
}

#[test]
fn loadgen_through_the_proxy_yields_full_ordered_span_chains() {
    let fleet = start_fleet(2);

    // every request traced: the loadgen client stamps a fresh id, the
    // proxy adopts it, the backend joins the chain via the wire extension
    let mut workload = traffic_mix("interactive").unwrap();
    workload.kernel = KernelKind::HadaCore;
    let report = loadgen::run(&LoadgenConfig {
        addr: fleet.proxy.addr().to_string(),
        mix: "interactive".to_string(),
        workload,
        qps: 0.0,
        requests: 60,
        clients: 2,
        dtype: DType::F32,
        trace_every: 1,
        ..Default::default()
    })
    .unwrap();
    assert!(report.ok > 0, "loadgen must complete requests: {}", report.line());
    assert_eq!(report.errors + report.disconnects, 0, "{}", report.line());

    let client = Client::connect(&fleet.proxy.addr().to_string()).unwrap();
    let events = client.trace_dump(0).unwrap();
    assert!(!events.is_empty(), "traced traffic must leave span events");

    // every batch's first sampled member carries the exec spans, so a
    // 60-request run must yield at least one complete chain — and every
    // complete chain must be correctly ordered
    let traces = by_trace(&events);
    let complete: Vec<_> =
        traces.iter().filter(|(_, evs)| has_full_chain(evs)).collect();
    assert!(
        !complete.is_empty(),
        "no trace out of {} carried the full span chain",
        traces.len()
    );
    for (trace, evs) in &complete {
        assert_ordered_chain(*trace, evs);
    }

    drop(client);
    fleet.teardown();
}

#[test]
fn one_traced_request_dumped_by_id_carries_the_full_chain() {
    let fleet = start_fleet(2);
    let client = Client::connect(&fleet.proxy.addr().to_string()).unwrap();

    let n = 1024;
    let rows = 2;
    let mut rng = Rng::new(0x0B5E_E2E);
    let data = rng.normal_vec(rows * n);
    let mut wire = WireRequest::from_f32(7, n, &data, KernelKind::HadaCore, DType::F32);
    let trace = next_trace_id();
    wire.trace = trace;
    let resp = client.transform(wire).unwrap();
    assert_eq!(resp.rows as usize, rows);

    // dump exactly this trace through the proxy (which merges its own
    // rings with the backends'); a single idle-fleet request is its
    // batch's only member, so its chain must be complete
    let events = client.trace_dump(trace).unwrap();
    assert!(events.iter().all(|e| e.trace == trace));
    assert!(
        has_full_chain(&events),
        "single traced request must carry the full chain, got: {:?}",
        events.iter().map(|e| e.stage.name()).collect::<Vec<_>>()
    );
    assert_ordered_chain(trace, &events);
    // arg plausibility: decode/admitted carry the row count
    assert!(events
        .iter()
        .any(|e| e.stage == Stage::Decode && e.arg == rows as u32));

    // an id nobody traced dumps empty
    assert!(client.trace_dump(0xDEAD_BEEF_0000_0001).unwrap().is_empty());

    drop(client);
    fleet.teardown();
}

#[test]
fn stats_text_through_the_proxy_unifies_all_layers() {
    let fleet = start_fleet(2);
    let client = Client::connect(&fleet.proxy.addr().to_string()).unwrap();

    // traffic first, so the counters are non-vacuous
    let n = 512;
    let mut rng = Rng::new(0x57A7);
    for i in 0..8u64 {
        let data = rng.normal_vec(2 * n);
        let wire = WireRequest::from_f32(i, n, &data, KernelKind::HadaCore, DType::F32);
        client.transform(wire).unwrap();
    }

    let text = client.stats_text().unwrap();
    let samples = parse_exposition(&text);
    let value = |name: &str| {
        samples
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.value)
            .sum::<f64>()
    };
    // one scrape spans all layers: coordinator, engine, serve, cluster
    assert!(value("hadacore_requests_total") >= 8.0, "coordinator series:\n{text}");
    assert!(value("hadacore_serve_requests_total") >= 8.0, "serve series:\n{text}");
    assert!(value("hadacore_exec_chunk_us_count") >= 1.0, "engine series:\n{text}");
    assert!(
        value("hadacore_cluster_forwarded_total") >= 8.0,
        "cluster series:\n{text}"
    );
    // present-at-zero: eagerly registered names render before ever firing
    assert!(
        text.contains("hadacore_cluster_retries_total"),
        "retries must render at 0:\n{text}"
    );
    // the computed series sample their pre-registry sources of truth
    assert!(
        samples
            .iter()
            .any(|s| s.name == "hadacore_simd_dispatch_total" && s.value >= 1.0),
        "simd dispatch series:\n{text}"
    );
    assert!(text.contains("hadacore_tune_decisions_total"), "tuner series:\n{text}");
    assert!(text.contains("hadacore_tracked_allocs_total"), "alloc series:\n{text}");

    drop(client);
    fleet.teardown();
}

#[test]
fn http_metrics_listener_serves_the_exposition() {
    // cold registry is fine: the listener renders whatever is registered
    let handle = serve_metrics("127.0.0.1:0").unwrap();
    let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n")
        .unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
    assert!(head.starts_with("HTTP/1.1 200"), "got: {head}");
    assert!(head.to_ascii_lowercase().contains("content-type: text/plain"));
    // the alloc series registers with the registry itself, so even a
    // scrape before any traffic carries it
    assert!(body.contains("hadacore_tracked_allocs_total"), "got: {body}");

    // anything but GET /metrics is a 404, and the listener survives it
    let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
    s.write_all(b"GET /nope HTTP/1.1\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 404"), "got: {raw}");

    handle.shutdown();
}
