//! End-to-end assertions over the full stack — the testable core of the
//! examples (accuracy study + attention serving) so that `cargo test`
//! alone certifies the headline claims:
//!
//! 1. the trained LM scores above chance on the MMLU-analog eval through
//!    the PJRT runtime (full three-layer composition);
//! 2. HadaCore rotation and exact-FWHT rotation produce identical model
//!    behaviour (the paper's §4.2 parity claim);
//! 3. with outlier-bearing weights, int8 attention shifts the model's
//!    decisions and Hadamard rotation restores them (the QuaRot claim).

use std::path::{Path, PathBuf};

use hadacore::runtime::xla;
use hadacore::runtime::{literal_f32, literal_i32, literal_to_f32, Runtime, Tensor};
use hadacore::util::json::Json;
use hadacore::util::prop::rel_l2;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

struct Eval {
    prefix_len: usize,
    questions: Vec<(Vec<i32>, Vec<Vec<i32>>, usize)>,
}

fn load_eval(dir: &Path) -> Eval {
    let text = std::fs::read_to_string(dir.join("eval.json")).unwrap();
    let root = Json::parse(&text).unwrap();
    let ints = |v: &Json| -> Vec<i32> {
        v.as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_f64)
            .map(|x| x as i32)
            .collect()
    };
    Eval {
        prefix_len: root.get("prefix_len").and_then(Json::as_usize).unwrap(),
        questions: root
            .get("questions")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|q| {
                (
                    q.get("prefix").map(&ints).unwrap(),
                    q.get("choices")
                        .and_then(Json::as_arr)
                        .unwrap()
                        .iter()
                        .map(&ints)
                        .collect(),
                    q.get("answer").and_then(Json::as_usize).unwrap(),
                )
            })
            .collect(),
    }
}

/// Score questions with one LM artifact; returns (accuracy, decisions).
fn score(
    rt: &Runtime,
    artifact: &str,
    weights: &[xla::Literal],
    eval: &Eval,
    max_q: usize,
) -> (f64, Vec<usize>) {
    let meta = rt.manifest().model.clone();
    let art = rt.load(artifact).unwrap();
    let k = eval.questions[0].1.len();
    let per_batch = meta.lm_batch / k;
    let questions = &eval.questions[..max_q.min(eval.questions.len())];

    let mut correct = 0usize;
    let mut decisions = Vec::new();
    let mut qi = 0;
    while qi < questions.len() {
        let group = &questions[qi..(qi + per_batch).min(questions.len())];
        let mut tokens = vec![0i32; meta.lm_batch * meta.seq_len];
        for (g, (prefix, choices, _)) in group.iter().enumerate() {
            for (c, choice) in choices.iter().enumerate() {
                let s = g * k + c;
                let row = &mut tokens[s * meta.seq_len..(s + 1) * meta.seq_len];
                row[..eval.prefix_len].copy_from_slice(prefix);
                row[eval.prefix_len..eval.prefix_len + choice.len()]
                    .copy_from_slice(choice);
            }
        }
        let tl = literal_i32(&tokens, &[meta.lm_batch, meta.seq_len]).unwrap();
        let mut refs: Vec<&xla::Literal> = vec![&tl];
        refs.extend(weights.iter());
        let logits = literal_to_f32(&art.execute_refs(&refs).unwrap()[0]).unwrap();

        for (g, (_, _, answer)) in group.iter().enumerate() {
            let mut best = (f64::MIN, 0usize);
            for c in 0..k {
                let s = g * k + c;
                let mut lp = 0.0f64;
                for t in eval.prefix_len..meta.seq_len {
                    let row = &logits
                        [(s * meta.seq_len + t - 1) * meta.vocab..(s * meta.seq_len + t) * meta.vocab];
                    let target = tokens[s * meta.seq_len + t] as usize;
                    let maxv = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
                    let lse: f64 =
                        row.iter().map(|&v| ((v as f64) - maxv).exp()).sum();
                    lp += (row[target] as f64 - maxv) - lse.ln();
                }
                if lp > best.0 {
                    best = (lp, c);
                }
            }
            decisions.push(best.1);
            if best.1 == *answer {
                correct += 1;
            }
        }
        qi += group.len();
    }
    (correct as f64 / questions.len() as f64, decisions)
}

fn outlier_weights(rt: &Runtime, scale: f32) -> Vec<xla::Literal> {
    let meta = rt.manifest().model.clone();
    let mut tensors: Vec<(String, Tensor)> = rt.weights().unwrap().ordered().to_vec();
    for (name, t) in tensors.iter_mut() {
        for &j in &[3usize, 17, 40, 77] {
            if name.ends_with(".wv") || name.ends_with(".wq") {
                for r in 0..meta.dim {
                    t.data[r * meta.dim + j] *= scale;
                }
            } else if name.ends_with(".wk") {
                for r in 0..meta.dim {
                    t.data[r * meta.dim + j] /= scale;
                }
            } else if name.ends_with(".wo") {
                for c in 0..meta.dim {
                    t.data[j * meta.dim + c] /= scale;
                }
            }
        }
    }
    tensors
        .iter()
        .map(|(_, t)| literal_f32(&t.data, &t.shape).unwrap())
        .collect()
}

#[test]
fn trained_model_beats_chance_through_full_stack() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::open(&dir).unwrap();
    let weights = rt.weights().unwrap().to_literals().unwrap();
    let eval = load_eval(&dir);
    let (acc, _) = score(&rt, "lm_fp16", &weights, &eval, 100);
    // 4 choices -> chance 0.25; the trained model must clearly beat it
    assert!(acc > 0.33, "accuracy {acc} not above chance");
}

#[test]
fn rotation_kernel_parity_on_model_decisions() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::open(&dir).unwrap();
    let weights = outlier_weights(&rt, 96.0);
    let eval = load_eval(&dir);
    let (acc_hc, dec_hc) = score(&rt, "lm_int8_rot_hadacore", &weights, &eval, 60);
    let (acc_bf, dec_bf) = score(&rt, "lm_int8_rot_butterfly", &weights, &eval, 60);
    // paper §4.2 parity: the two rotation kernels produce the same model
    assert_eq!(dec_hc, dec_bf, "kernel decisions must match");
    assert!((acc_hc - acc_bf).abs() < 1e-9);
}

#[test]
fn rotation_restores_int8_decisions_with_outlier_weights() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::open(&dir).unwrap();
    let weights = outlier_weights(&rt, 96.0);
    let eval = load_eval(&dir);
    let n_q = 80;
    let (_, dec_clean) = score(&rt, "lm_fp16", &weights, &eval, n_q);
    let (_, dec_int8) = score(&rt, "lm_int8_norot", &weights, &eval, n_q);
    let (_, dec_rot) = score(&rt, "lm_int8_rot_hadacore", &weights, &eval, n_q);

    let flips = |a: &[usize], b: &[usize]| {
        a.iter().zip(b.iter()).filter(|(x, y)| x != y).count()
    };
    let f_int8 = flips(&dec_clean, &dec_int8);
    let f_rot = flips(&dec_clean, &dec_rot);
    eprintln!("decision flips vs fp16: int8={f_int8}, int8+rotation={f_rot}");
    assert!(
        f_rot < f_int8,
        "rotation should restore fp16 decisions: {f_rot} !< {f_int8}"
    );
}

#[test]
fn attention_artifact_logits_fidelity_ordering() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::open(&dir).unwrap();
    let weights = outlier_weights(&rt, 96.0);
    let eval = load_eval(&dir);
    let meta = rt.manifest().model.clone();

    // run one batch through fp16 / int8 / int8+rot and order the errors
    let (prefix, choices, _) = &eval.questions[0];
    let mut tokens = vec![0i32; meta.lm_batch * meta.seq_len];
    for (c, choice) in choices.iter().enumerate() {
        let row = &mut tokens[c * meta.seq_len..(c + 1) * meta.seq_len];
        row[..eval.prefix_len].copy_from_slice(prefix);
        row[eval.prefix_len..eval.prefix_len + choice.len()].copy_from_slice(choice);
    }
    let tl = literal_i32(&tokens, &[meta.lm_batch, meta.seq_len]).unwrap();
    let run = |name: &str| {
        let art = rt.load(name).unwrap();
        let mut refs: Vec<&xla::Literal> = vec![&tl];
        refs.extend(weights.iter());
        literal_to_f32(&art.execute_refs(&refs).unwrap()[0]).unwrap()
    };
    let clean = run("lm_fp16");
    let e_int8 = rel_l2(&run("lm_int8_norot"), &clean);
    let e_rot = rel_l2(&run("lm_int8_rot_hadacore"), &clean);
    eprintln!("logit error vs fp16: int8={e_int8:.5}, int8+rot={e_rot:.5}");
    assert!(e_rot < e_int8 * 0.75, "rotation must cut int8 logit error");
}
