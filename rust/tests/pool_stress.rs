//! Deterministic-seed concurrency stress for the worker pool: many
//! submitting threads hammer chunk claiming and the two-phase epilogue
//! machinery simultaneously, on an engine configured for maximal chunk
//! churn (tiny chunks, many lanes). Catches lost updates (a chunk
//! claimed twice / never), ordering bugs (phase 2 starting before every
//! phase-1 chunk merged its amax), and cross-job interference (chunks
//! of concurrent jobs writing each other's buffers).
//!
//! Payloads are seeded per (submitter, iteration), so every run checks
//! the same data against the same single-threaded references — only the
//! scheduling varies. std threads only, no new dependencies.

use std::sync::Arc;

use hadacore::exec::{ExecConfig, ExecEngine, TunePolicy};
use hadacore::hadamard::{fwht_f32, FwhtOptions, KernelKind};
use hadacore::quant::{
    fp8_quantize_slice, int_quantize_grouped, Epilogue, Fp8Format, IntBits,
    QuantScales,
};
use hadacore::util::f16::{Element, F16};
use hadacore::util::rng::Rng;

/// An engine built for churn: 8 lanes, chunks as small as one row so
/// every batch fans into many claims with a ragged tail.
fn churn_engine() -> Arc<ExecEngine> {
    Arc::new(ExecEngine::new(ExecConfig {
        threads: 8,
        chunks_per_thread: 8,
        min_chunk_elems: 64,
        // pin the depth so the stress run exercises fused tiles without
        // spending startup time in the micro-measurement
        tune: TunePolicy::FixedDepth(2),
    }))
}

/// Deterministic payload for (submitter, iteration): integer-valued so
/// the raw transform is exact and a lost/duplicated chunk produces a
/// gross integer mismatch, never a tolerance question.
fn payload(submitter: u64, iter: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::new(0x57E5 ^ (submitter << 32) ^ iter);
    (0..len).map(|_| rng.below(9) as f32 - 4.0).collect()
}

#[test]
fn concurrent_submitters_hammer_chunk_claiming() {
    // 16 submitters × 6 iterations × ragged shapes, all sharing one
    // 8-lane pool: every response must equal the direct single-call
    // transform bit for bit
    let engine = churn_engine();
    let shapes = [(37usize, 256usize), (13, 768), (29, 512), (5, 1024)];
    std::thread::scope(|s| {
        for submitter in 0..16u64 {
            let engine = Arc::clone(&engine);
            s.spawn(move || {
                for iter in 0..6u64 {
                    let (rows, n) = shapes[(submitter as usize + iter as usize) % shapes.len()];
                    let x = payload(submitter, iter, rows * n);
                    let opts = FwhtOptions::raw();
                    let mut want = x.clone();
                    fwht_f32(KernelKind::HadaCore, &mut want, n, &opts);
                    let mut got = x;
                    engine.run_f32(KernelKind::HadaCore, &mut got, n, &opts);
                    assert_eq!(
                        want, got,
                        "submitter {submitter} iter {iter} {rows}x{n}"
                    );
                }
            });
        }
    });
    let stats = engine.stats();
    assert!(stats.jobs > 0, "stress batches must shard: {stats:?}");
    assert!(
        stats.chunks > stats.jobs * 4,
        "chunk churn expected (tiny chunks): {stats:?}"
    );
}

#[test]
fn concurrent_two_phase_epilogues_never_lose_or_reorder_updates() {
    // the two-phase FP8 job is the ordering-sensitive path: phase 2's
    // scale is only correct if *every* phase-1 chunk merged its amax
    // before the latch opened. Hammer it from 12 submitters and check
    // scales + bytes against the sequential reference; plant the batch
    // amax deep in one chunk so a premature phase 2 is guaranteed to
    // pick a wrong scale.
    let engine = churn_engine();
    std::thread::scope(|s| {
        for submitter in 0..12u64 {
            let engine = Arc::clone(&engine);
            s.spawn(move || {
                for iter in 0..5u64 {
                    let (rows, n) = (23usize, 512usize);
                    let mut x = payload(submitter, iter, rows * n);
                    // the extreme element lands in a different chunk per
                    // (submitter, iter)
                    let hot = ((submitter * 7 + iter * 3) as usize) % (rows * n);
                    x[hot] = 3.0e4;
                    let opts = FwhtOptions::normalized(n);

                    let mut want = x.clone();
                    fwht_f32(KernelKind::HadaCore, &mut want, n, &opts);
                    let want_scale =
                        fp8_quantize_slice(&mut want, Fp8Format::E4M3);

                    let mut got = x;
                    let scales = engine.run_f32_with_epilogue(
                        KernelKind::HadaCore,
                        &mut got,
                        n,
                        &opts,
                        Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
                    );
                    assert_eq!(
                        scales,
                        QuantScales::PerTensor(want_scale),
                        "submitter {submitter} iter {iter}: amax lost or \
                         phase ordering broken"
                    );
                    assert_eq!(want, got, "submitter {submitter} iter {iter}");
                }
            });
        }
    });
}

#[test]
fn concurrent_grouped_epilogues_write_disjoint_scale_slots() {
    // grouped INT8 writes per-chunk scale slots through a raw pointer;
    // concurrent jobs must never interleave slots
    let engine = churn_engine();
    std::thread::scope(|s| {
        for submitter in 0..10u64 {
            let engine = Arc::clone(&engine);
            s.spawn(move || {
                for iter in 0..5u64 {
                    let (rows, n, group) = (19usize, 256usize, 32usize);
                    let x = payload(submitter, iter, rows * n);
                    let opts = FwhtOptions::normalized(n);

                    let mut want = x.clone();
                    fwht_f32(KernelKind::HadaCore, &mut want, n, &opts);
                    let want_scales =
                        int_quantize_grouped(&mut want, group, IntBits::Int8);

                    let mut got = x;
                    let scales = engine.run_f32_with_epilogue(
                        KernelKind::HadaCore,
                        &mut got,
                        n,
                        &opts,
                        Epilogue::QuantInt8 { group },
                    );
                    assert_eq!(scales, QuantScales::PerGroup(want_scales));
                    assert_eq!(want, got, "submitter {submitter} iter {iter}");
                }
            });
        }
    });
}

#[test]
fn mixed_dtype_and_epilogue_traffic_shares_one_pool() {
    // the realistic worst case: f32 plain, f32 fp8, and f16 plain jobs
    // interleaving on the same lanes — per-thread scratch buffers and
    // stage dispatch must never cross wires
    let engine = churn_engine();
    std::thread::scope(|s| {
        for submitter in 0..12u64 {
            let engine = Arc::clone(&engine);
            s.spawn(move || {
                for iter in 0..4u64 {
                    let (rows, n) = (17usize, 512usize);
                    let x = payload(submitter, iter, rows * n);
                    let opts = FwhtOptions::normalized(n);
                    match submitter % 3 {
                        0 => {
                            let mut want = x.clone();
                            fwht_f32(KernelKind::HadaCore, &mut want, n, &opts);
                            let mut got = x;
                            engine.run_f32(KernelKind::HadaCore, &mut got, n, &opts);
                            assert_eq!(want, got);
                        }
                        1 => {
                            let mut want = x.clone();
                            fwht_f32(KernelKind::HadaCore, &mut want, n, &opts);
                            let want_scale =
                                fp8_quantize_slice(&mut want, Fp8Format::E5M2);
                            let mut got = x;
                            let scales = engine.run_f32_with_epilogue(
                                KernelKind::HadaCore,
                                &mut got,
                                n,
                                &opts,
                                Epilogue::QuantFp8 { fmt: Fp8Format::E5M2 },
                            );
                            assert_eq!(scales, QuantScales::PerTensor(want_scale));
                            assert_eq!(want, got);
                        }
                        _ => {
                            let h: Vec<F16> =
                                x.iter().map(|&v| F16::from_f32(v)).collect();
                            let mut want = h.clone();
                            hadacore::hadamard::fwht_generic(
                                KernelKind::HadaCore,
                                &mut want,
                                n,
                                &opts,
                            );
                            let mut got = h;
                            engine.run(KernelKind::HadaCore, &mut got, n, &opts);
                            assert_eq!(want, got);
                        }
                    }
                }
            });
        }
    });
    let stats = engine.stats();
    assert!(stats.epilogue_runs >= 16, "fp8 arm must have run: {stats:?}");
}
