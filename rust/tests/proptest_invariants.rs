//! Property-based tests over coordinator + kernel invariants (the brief's
//! L3 requirement: routing, batching, state under randomised inputs).
//!
//! Uses the in-repo `util::prop` driver (proptest is unavailable offline):
//! randomised cases with replayable seeds, `PROP_CASES` scales depth.

use std::collections::HashMap;
use std::time::Duration;

use hadacore::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RouterConfig, TransformRequest,
};
use hadacore::exec::{ExecConfig, ExecEngine, TunePolicy};
use hadacore::hadamard::hadacore::{
    fwht_hadacore_f32_cfg, fwht_hadacore_f32_planned_depth, HadaCoreConfig,
    HadaCorePlan,
};
use hadacore::hadamard::{
    apply_signs, fwht_dao_f32, fwht_f32, fwht_hadacore_f32, fwht_scalar_f32, sign_vector,
    FwhtOptions, KernelKind, Prologue,
};
use hadacore::quant::{fake_quantize, Epilogue, Scheme};
use hadacore::util::prop::{
    assert_close, check, integer_vec, max_abs_diff, random_supported_size, rel_l2,
};
use hadacore::util::rng::Rng;

fn coordinator(workers: usize) -> Coordinator {
    Coordinator::start(
        None,
        CoordinatorConfig {
            workers,
            batcher: BatcherConfig {
                max_delay: Duration::from_micros(100),
                work_conserving: true,
            },
            router: RouterConfig::default(),
            idle_timeout: Duration::from_millis(5),
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn prop_responses_match_requests_exactly() {
    // no request is lost, duplicated, or mismatched under random mixes of
    // sizes, rows, kernels and scales
    let coord = coordinator(4);
    check("request/response bijection", 12, |rng| {
        let count = rng.range(5, 40);
        let mut expected: HashMap<u64, Vec<f32>> = HashMap::new();
        let mut handles = Vec::new();
        for i in 0..count {
            let n = 1usize << rng.range(4, 12);
            let rows = rng.range(1, 4);
            let data = rng.normal_vec(rows * n);
            let kernel = match rng.below(3) {
                0 => KernelKind::Scalar,
                1 => KernelKind::Dao,
                _ => KernelKind::HadaCore,
            };
            let scale = if rng.chance(0.3) { Some(rng.f32() + 0.5) } else { None };
            let mut want = data.clone();
            fwht_f32(
                kernel,
                &mut want,
                n,
                &match scale {
                    Some(s) => FwhtOptions::with_scale(s),
                    None => FwhtOptions::normalized(n),
                },
            );
            let id = rng.next_u64() ^ i as u64;
            expected.insert(id, want);
            let mut req = TransformRequest::new(id, n, data);
            req.kernel = kernel;
            req.scale = scale;
            handles.push(coord.submit(req).unwrap());
        }
        for h in handles {
            let resp = h.recv().unwrap().unwrap();
            let want = expected.remove(&resp.id).expect("unknown or duplicate id");
            assert_close(&resp.data, &want, 1e-3, 1e-2);
        }
        assert!(expected.is_empty(), "lost responses: {}", expected.len());
    });
    coord.shutdown();
}

#[test]
fn prop_base_matrices_are_symmetric_orthogonal_involutions() {
    use hadacore::hadamard::hadamard_base;
    // orthogonality H_B · H_Bᵀ = B·I, symmetry (which makes the
    // normalized transform an involution), and ±1 entries — exact
    // arithmetic, so asserted with == not tolerances
    for b in [12usize, 20, 28, 40] {
        let h = hadamard_base(b);
        for i in 0..b {
            for j in 0..b {
                assert!(
                    h[i * b + j] == 1.0 || h[i * b + j] == -1.0,
                    "H{b}[{i}][{j}] must be ±1"
                );
                assert_eq!(h[i * b + j], h[j * b + i], "H{b} must be symmetric");
                let dot: f32 = (0..b).map(|k| h[i * b + k] * h[j * b + k]).sum();
                let want = if i == j { b as f32 } else { 0.0 };
                assert_eq!(dot, want, "H{b} rows {i},{j}");
            }
        }
    }
}

#[test]
fn prop_non_pow2_involution_and_kernel_agreement() {
    // involution-up-to-scale and three-kernel agreement across the whole
    // B * 2^k family at random k
    check("non-pow2 involution + agreement", 25, |rng| {
        let base = [12usize, 20, 28, 40][rng.below(4)];
        let n = base << rng.range(0, 8); // up to 40 * 256 = 10240
        let x = rng.normal_vec(n);
        let opts = FwhtOptions::normalized(n);

        let mut y = x.clone();
        fwht_hadacore_f32(&mut y, n, &opts);
        fwht_hadacore_f32(&mut y, n, &opts);
        assert_close(&y, &x, 1e-3, 1e-3);

        let mut a = x.clone();
        let mut b = x.clone();
        let mut c = x;
        fwht_scalar_f32(&mut a, n, &opts);
        fwht_dao_f32(&mut b, n, &opts);
        fwht_hadacore_f32(&mut c, n, &opts);
        assert_close(&b, &a, 1e-3, 1e-3);
        assert_close(&c, &a, 1e-3, 1e-3);
    });
}

#[test]
fn prop_differential_all_paths_agree_bit_for_bit_on_integer_payloads() {
    // The differential fuzz harness (ISSUE 4): randomized rows × size ×
    // lanes × chunk boundaries × fusion depths, asserting
    //   scalar == dao == hadacore == planned == planned@depth == engine
    // With integer payloads in [-4, 4] and the raw scale every
    // intermediate is an exact small integer (n·amp < 2^24 across the
    // drawn family), and all three kernels factor the same butterfly
    // network — so the assertion is **bit equality across everything**,
    // the strongest oracle this suite has. Lanes {1, 3, 8} × random
    // chunk floors guarantee random chunk boundaries; a fresh engine per
    // case keeps the drawn (lanes, chunk, depth) combination honest.
    check("differential: kernels × plans × depths × engines", 16, |rng| {
        let n = random_supported_size(rng, 9); // up to 40·512 = 20480
        let rows = rng.range(1, 6);
        let x = integer_vec(rng, rows * n, 4);
        let opts = FwhtOptions::raw();

        let mut scalar = x.clone();
        fwht_scalar_f32(&mut scalar, n, &opts);
        let mut dao = x.clone();
        fwht_dao_f32(&mut dao, n, &opts);
        assert_eq!(scalar, dao, "scalar vs dao: n={n} rows={rows}");
        let mut hada = x.clone();
        fwht_hadacore_f32(&mut hada, n, &opts);
        assert_eq!(scalar, hada, "scalar vs hadacore: n={n} rows={rows}");

        for cfg in [
            HadaCoreConfig { residual: hadacore::hadamard::hadacore::ResidualMode::BlockDiagonal },
            HadaCoreConfig { residual: hadacore::hadamard::hadacore::ResidualMode::SmallFactor },
        ] {
            let mut direct = x.clone();
            fwht_hadacore_f32_cfg(&mut direct, n, &opts, &cfg);
            let plan = HadaCorePlan::new(n, &cfg);
            for depth in 1..=plan.max_fusion_depth() {
                let mut fused = x.clone();
                fwht_hadacore_f32_planned_depth(&mut fused, &plan, &opts, depth);
                assert_eq!(
                    direct, fused,
                    "planned@{depth} vs cfg: n={n} {:?}",
                    cfg.residual
                );
            }
            // both residual modes compute the same exact integers
            assert_eq!(scalar, direct, "cfg {:?} vs scalar: n={n}", cfg.residual);
        }

        // engines: random lane count, random chunk floor, random depth
        let threads = [1usize, 3, 8][rng.below(3)];
        let min_chunk = 1usize << rng.range(6, 12);
        let depth = rng.range(1, 4);
        let engine = ExecEngine::new(ExecConfig {
            threads,
            chunks_per_thread: rng.range(1, 5),
            min_chunk_elems: min_chunk,
            tune: TunePolicy::FixedDepth(depth),
        });
        let mut engine_out = x.clone();
        engine.run_f32(KernelKind::HadaCore, &mut engine_out, n, &opts);
        assert_eq!(
            scalar, engine_out,
            "engine vs scalar: n={n} rows={rows} t={threads} chunk>={min_chunk} d={depth}"
        );
    });
}

#[test]
fn prop_differential_real_payloads_close_and_hadacore_chain_exact() {
    // real-valued twin of the test above: cross-kernel comparisons drop
    // to tolerances (different butterfly associations round differently
    // in principle), but the hadacore chain (cfg == planned@every depth
    // == engine) must stay bit-exact — fusion and sharding are
    // scheduling, not arithmetic
    check("differential: real payloads", 12, |rng| {
        let n = random_supported_size(rng, 8);
        let rows = rng.range(1, 4);
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);

        let mut scalar = x.clone();
        fwht_scalar_f32(&mut scalar, n, &opts);
        let mut hada = x.clone();
        fwht_hadacore_f32(&mut hada, n, &opts);
        assert_close(&hada, &scalar, 1e-3, 1e-3);

        let plan = HadaCorePlan::new(n, &HadaCoreConfig::default());
        for depth in 1..=plan.max_fusion_depth() {
            let mut fused = x.clone();
            fwht_hadacore_f32_planned_depth(&mut fused, &plan, &opts, depth);
            assert_eq!(hada, fused, "depth {depth} n={n}");
        }

        let engine = ExecEngine::new(ExecConfig {
            threads: [1usize, 4][rng.below(2)],
            chunks_per_thread: 2,
            min_chunk_elems: 1 << rng.range(7, 11),
            tune: TunePolicy::FixedDepth(rng.range(1, 4)),
        });
        let mut engine_out = x;
        engine.run_f32(KernelKind::HadaCore, &mut engine_out, n, &opts);
        assert_eq!(hada, engine_out, "engine n={n} rows={rows}");
    });
}

#[test]
fn prop_kernels_agree_on_random_inputs() {
    check("three kernels agree", 40, |rng| {
        let n = 1usize << rng.range(1, 15);
        let rows = rng.range(1, 3);
        let x = rng.normal_vec(rows * n);
        let mut a = x.clone();
        let mut b = x.clone();
        let mut c = x;
        let opts = FwhtOptions::normalized(n);
        fwht_scalar_f32(&mut a, n, &opts);
        fwht_dao_f32(&mut b, n, &opts);
        fwht_hadacore_f32(&mut c, n, &opts);
        assert_close(&b, &a, 1e-3, 1e-3);
        assert_close(&c, &a, 1e-3, 1e-3);
    });
}

#[test]
fn prop_transform_is_orthogonal_on_adversarial_inputs() {
    // norm preservation + involution must hold for heavy-tailed, constant,
    // sparse and alternating inputs — not just Gaussians
    check("orthogonality on adversarial inputs", 30, |rng| {
        let n = 1usize << rng.range(2, 13);
        let kind_sel = rng.below(4);
        let x: Vec<f32> = (0..n)
            .map(|i| match kind_sel {
                0 => rng.outlier_normal(0.05, 100.0),
                1 => 3.25,
                2 => {
                    if rng.chance(0.05) {
                        rng.normal_f32() * 50.0
                    } else {
                        0.0
                    }
                }
                _ => if i % 2 == 0 { 1.0 } else { -1.0 },
            })
            .collect();
        let mut y = x.clone();
        let opts = FwhtOptions::normalized(n);
        fwht_hadacore_f32(&mut y, n, &opts);
        let nx: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
        let ny: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!(
            (nx - ny).abs() <= nx.max(1e-9) * 1e-3,
            "norm drift {nx} -> {ny}"
        );
        fwht_hadacore_f32(&mut y, n, &opts);
        assert!(
            max_abs_diff(&y, &x)
                <= 1e-3 * (1.0 + x.iter().fold(0.0f32, |m, v| m.max(v.abs()))),
            "involution failed"
        );
    });
}

#[test]
fn prop_parseval_energy_concentration() {
    // a constant vector concentrates all energy in coefficient 0; a
    // Walsh function (row k of H) concentrates it in coefficient k
    check("parseval concentration", 20, |rng| {
        let n = 1usize << rng.range(2, 10);
        let k = rng.below(n);
        let x: Vec<f32> = (0..n)
            .map(|j| hadacore::hadamard::matrices::hadamard_entry(k, j))
            .collect();
        let mut y = x;
        fwht_hadacore_f32(&mut y, n, &FwhtOptions::normalized(n));
        for (j, v) in y.iter().enumerate() {
            if j == k {
                assert!((v - (n as f32).sqrt()).abs() < 1e-2, "peak at {j}: {v}");
            } else {
                assert!(v.abs() < 1e-2, "leakage at {j}: {v}");
            }
        }
    });
}

#[test]
fn prop_quantisation_error_bounded_and_rotation_helps() {
    check("quant error bounds", 25, |rng| {
        let n = 1usize << rng.range(6, 12);
        let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        // random outlier channel pattern
        let stride = 1 << rng.range(3, 5);
        for i in (0..n).step_by(stride) {
            x[i] *= 30.0;
        }
        let mut direct = x.clone();
        fake_quantize(&mut direct, Scheme::Int8);
        let e_direct = rel_l2(&direct, &x);

        let opts = FwhtOptions::normalized(n);
        let mut rot = x.clone();
        fwht_hadacore_f32(&mut rot, n, &opts);
        fake_quantize(&mut rot, Scheme::Int8);
        fwht_hadacore_f32(&mut rot, n, &opts);
        let e_rot = rel_l2(&rot, &x);

        assert!(e_direct < 0.5, "int8 error blew up: {e_direct}");
        assert!(
            e_rot < e_direct * 1.05,
            "rotation should not hurt int8: {e_rot} vs {e_direct}"
        );
    });
}

#[test]
fn prop_rotation_roundtrip_bit_exact_on_integer_payloads() {
    // unrotate(rotate(x)) == n·x BIT-exact: rotate = sign flip + raw
    // transform (fused prologue through the engine), unrotate = raw
    // transform + the same sign flip. With integer payloads in [-4, 4]
    // every intermediate telescopes to a partial Hadamard transform
    // bounded by base·4n < 2^24, so both transforms are exact integer
    // arithmetic and the sign flips are exact ±1 multiplies — the
    // round-trip must reproduce n·x to the bit, across random sizes,
    // seeds, lane counts and chunk boundaries.
    check("rotation round-trip: integer payloads", 16, |rng| {
        let n = random_supported_size(rng, 8); // up to 40·256 = 10240
        let rows = rng.range(1, 5);
        let seed = rng.next_u64();
        let x = integer_vec(rng, rows * n, 4);
        let opts = FwhtOptions::raw();
        let engine = ExecEngine::new(ExecConfig {
            threads: [1usize, 3, 8][rng.below(3)],
            chunks_per_thread: rng.range(1, 5),
            min_chunk_elems: 1usize << rng.range(6, 12),
            tune: TunePolicy::FixedDepth(rng.range(1, 4)),
        });
        let kernel = [KernelKind::Scalar, KernelKind::Dao, KernelKind::HadaCore]
            [rng.below(3)];

        let mut data = x.clone();
        // rotate: x ← H·(D·x), fused prologue
        engine.run_with_stages(
            kernel,
            &mut data,
            n,
            &opts,
            Prologue::SignFlip { seed },
            Epilogue::None,
        );
        // unrotate: x ← D·(H·x)
        engine.run(kernel, &mut data, n, &opts);
        Prologue::SignFlip { seed }.unapply(&mut data, n);

        // n·x is an exact f32 product (integer result < 2^24)
        let want: Vec<f32> = x.iter().map(|v| v * n as f32).collect();
        assert_eq!(
            data, want,
            "round-trip drift: kernel={kernel:?} n={n} rows={rows} seed={seed:#x}"
        );
    });
}

#[test]
fn prop_fused_prologue_matches_premultiply_all_kernels() {
    // fused sign-flip prologue == explicit apply_signs + plain
    // transform, bit for bit, on arbitrary real payloads — multiplying
    // by ±1.0 is exact, so fusion placement cannot change a single bit.
    // Random kernels × engine shapes × scales, both engine and direct
    // kernel reference.
    check("fused prologue == premultiply", 20, |rng| {
        let n = random_supported_size(rng, 8);
        let rows = rng.range(1, 5);
        let seed = rng.next_u64();
        let x = rng.normal_vec(rows * n);
        let opts = if rng.chance(0.5) {
            FwhtOptions::normalized(n)
        } else {
            FwhtOptions::with_scale(rng.f32() + 0.5)
        };
        let kernel = [KernelKind::Scalar, KernelKind::Dao, KernelKind::HadaCore]
            [rng.below(3)];
        let engine = ExecEngine::new(ExecConfig {
            threads: [1usize, 4][rng.below(2)],
            chunks_per_thread: 2,
            min_chunk_elems: 1usize << rng.range(6, 11),
            tune: TunePolicy::FixedDepth(rng.range(1, 4)),
        });

        // reference: unfused premultiply, then the plain direct kernel
        let signs = sign_vector(seed, n);
        let mut want = x.clone();
        apply_signs(&mut want, &signs);
        fwht_f32(kernel, &mut want, n, &opts);

        // fused engine path
        let mut fused = x.clone();
        engine.run_with_stages(
            kernel,
            &mut fused,
            n,
            &opts,
            Prologue::SignFlip { seed },
            Epilogue::None,
        );
        assert_eq!(fused, want, "engine fused: kernel={kernel:?} n={n} rows={rows}");

        // premultiplied engine run must also agree (fusion is placement,
        // not arithmetic)
        let mut pre = x;
        apply_signs(&mut pre, &signs);
        engine.run_f32(kernel, &mut pre, n, &opts);
        assert_eq!(pre, want, "engine premultiplied: kernel={kernel:?} n={n}");
    });
}

#[test]
fn prop_sign_vector_is_a_pure_function_of_seed_and_n() {
    // every path that materialises the ±1 diagonal — direct
    // sign_vector, the engine's Prologue::signs, and a wire-protocol
    // round-trip — must agree byte-for-byte
    use hadacore::serve::wire::{decode_frame, Frame, WireRequest, DEFAULT_MAX_FRAME_BYTES};
    use hadacore::util::f16::DType;
    check("sign vector purity", 30, |rng| {
        let n = random_supported_size(rng, 7);
        let seed = rng.next_u64();

        let direct = sign_vector(seed, n);
        assert_eq!(direct.len(), n);
        assert!(direct.iter().all(|s| *s == 1.0 || *s == -1.0));
        // deterministic, and the engine's materialisation path agrees
        assert_eq!(sign_vector(seed, n), direct);
        let engine_signs = Prologue::SignFlip { seed }.signs(n).unwrap();
        assert_eq!(
            engine_signs.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            direct.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
        );

        // wire round-trip: the seed survives framing, and the decoded
        // prologue derives the identical vector
        let mut req = WireRequest::from_f32(
            7,
            n as u32,
            &vec![0.5f32; n],
            KernelKind::HadaCore,
            DType::F32,
        );
        req.prologue = Prologue::SignFlip { seed };
        let bytes = Frame::Request(req).encode();
        let (frame, _) = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES)
            .expect("decodes")
            .expect("complete");
        let Frame::Request(decoded) = frame else {
            panic!("not a request")
        };
        let wire_signs = decoded.prologue.signs(n).expect("rotated");
        assert_eq!(
            wire_signs.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            direct.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
        );

        // non-vacuity: a different seed draws a different stream (skip
        // tiny n where a 2^-n collision is plausible)
        if n >= 32 {
            assert_ne!(sign_vector(seed ^ 1, n), direct, "seed must matter (n={n})");
        }
    });
}

#[test]
fn prop_forced_simd_backend_bit_identical_to_scalar_table() {
    // Random dispatch forcing (ISSUE 8): each case draws a reachable
    // SIMD backend, a kernel family, an engine shape and an integer
    // payload, runs the identical transform once under the forced
    // scalar table and once under the forced vector table, and demands
    // **bit equality** — compared via to_bits, so a -0.0/+0.0 skew
    // (the zero-skipping hazard in the base stage) cannot hide behind
    // `-0.0 == 0.0`. Forcing is process-global; sibling tests in this
    // binary tolerate it because the very property under test is that
    // the bits are backend-independent.
    use hadacore::hadamard::simd::{self, Backend};
    check("forced dispatch: vector bits == scalar bits", 16, |rng| {
        let reachable: Vec<Backend> =
            Backend::all().into_iter().filter(|&b| simd::reachable(b)).collect();
        let backend = reachable[rng.below(reachable.len())];
        let n = random_supported_size(rng, 9); // up to 40·512 = 20480
        let rows = rng.range(1, 6);
        let x = integer_vec(rng, rows * n, 4);
        let opts = FwhtOptions::raw();
        let kernel = [KernelKind::Dao, KernelKind::HadaCore][rng.below(2)];
        let engine = ExecEngine::new(ExecConfig {
            threads: [1usize, 3, 8][rng.below(3)],
            chunks_per_thread: rng.range(1, 5),
            min_chunk_elems: 1usize << rng.range(6, 12),
            tune: TunePolicy::FixedDepth(rng.range(1, 4)),
        });
        let run = |data: &mut Vec<f32>, direct: bool| {
            if direct {
                fwht_f32(kernel, data, n, &opts);
            } else {
                engine.run_f32(kernel, data, n, &opts);
            }
        };
        let direct = rng.chance(0.5);

        let prev = simd::force(Backend::Scalar).expect("scalar always reachable");
        let mut want = x.clone();
        run(&mut want, direct);
        simd::force(backend).expect("drawn backend reachable");
        let before = simd::dispatch_count(backend);
        let mut got = x.clone();
        run(&mut got, direct);
        let after = simd::dispatch_count(backend);
        simd::force(prev).expect("restore");

        assert!(after > before, "non-vacuity: {} never dispatched", backend.name());
        let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(
            got_bits, want_bits,
            "{} diverged from scalar table: kernel={kernel:?} n={n} rows={rows} \
             direct={direct}",
            backend.name()
        );
    });
}

#[test]
fn prop_batcher_state_never_leaks_rows() {
    // after any request pattern completes, the batcher holds zero rows
    let coord = coordinator(2);
    check("no queued rows after drain", 10, |rng| {
        let count = rng.range(1, 30);
        let handles: Vec<_> = (0..count)
            .map(|i| {
                let n = 1usize << rng.range(4, 10);
                coord
                    .submit(TransformRequest::new(i as u64, n, vec![1.0; n]))
                    .unwrap()
            })
            .collect();
        for h in handles {
            h.recv().unwrap().unwrap();
        }
    });
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.submitted, snap.completed);
    coord.shutdown();
}

#[test]
fn prop_scale_linearity_through_server() {
    let coord = coordinator(2);
    check("scale linearity", 10, |rng| {
        let n = 1usize << rng.range(4, 10);
        let x = rng.normal_vec(n);
        let s = rng.f32() * 3.0 + 0.1;
        let mut a = TransformRequest::new(1, n, x.clone());
        a.scale = Some(s);
        let mut b = TransformRequest::new(2, n, x);
        b.scale = Some(1.0);
        let ra = coord.transform(a).unwrap();
        let rb = coord.transform(b).unwrap();
        let scaled: Vec<f32> = rb.data.iter().map(|v| v * s).collect();
        assert_close(&ra.data, &scaled, 1e-3, 1e-2);
    });
    coord.shutdown();
}
