//! Property tests for the serving wire protocol (`serve/wire.rs`) — the
//! `protocol-fuzz` CI gate.
//!
//! Three invariants, each driven over randomized inputs:
//!
//! 1. **Round-trip**: any well-formed frame encodes then decodes to an
//!    equal value (payload bytes compared exactly, so this holds for
//!    arbitrary payload bit patterns).
//! 2. **Truncation**: every strict prefix of a valid encoding decodes to
//!    "need more bytes" — never a frame, never a panic.
//! 3. **Garbage**: arbitrary byte soup (and single-byte corruptions of
//!    valid frames) never panics and never over-allocates; the decoder
//!    answers with a frame, "need more", or a descriptive error.

use hadacore::hadamard::{KernelKind, Prologue};
use hadacore::quant::{Epilogue, Fp8Format, QuantScales};
use hadacore::serve::wire::{
    decode_frame, parse_body, ErrorCode, Frame, WireError, WireRequest, WireResponse,
    WireStats, DEFAULT_MAX_FRAME_BYTES,
};
use hadacore::util::f16::DType;
use hadacore::util::prop::check;
use hadacore::util::rng::Rng;

fn random_dtype(rng: &mut Rng) -> DType {
    [DType::F32, DType::F16, DType::BF16][rng.below(3)]
}

fn random_kernel(rng: &mut Rng) -> KernelKind {
    [KernelKind::Scalar, KernelKind::Dao, KernelKind::HadaCore][rng.below(3)]
}

fn random_epilogue(rng: &mut Rng) -> Epilogue {
    match rng.below(4) {
        0 => Epilogue::None,
        1 => Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
        2 => Epilogue::QuantFp8 { fmt: Fp8Format::E5M2 },
        _ => Epilogue::QuantInt8 { group: 1 + rng.below(64) },
    }
}

fn random_bytes(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

/// Printable-ish random string (valid UTF-8 by construction).
fn random_string(rng: &mut Rng, max: usize) -> String {
    let len = rng.below(max + 1);
    (0..len)
        .map(|_| char::from(b'a' + (rng.below(26) as u8)))
        .collect()
}

fn random_frame(rng: &mut Rng) -> Frame {
    match rng.below(8) {
        0 => {
            let dtype = random_dtype(rng);
            let n = 1 + rng.below(64);
            let rows = rng.below(4);
            Frame::Request(WireRequest {
                id: rng.next_u64(),
                n: n as u32,
                rows: rows as u32,
                kernel: random_kernel(rng),
                dtype,
                // finite scales only: NaN breaks PartialEq round-trip
                // comparison (and the router rejects them anyway)
                scale: rng.chance(0.5).then(|| rng.normal_f32()),
                force_native: rng.chance(0.5),
                prologue: if rng.chance(0.5) {
                    Prologue::SignFlip { seed: rng.next_u64() }
                } else {
                    Prologue::None
                },
                epilogue: random_epilogue(rng),
                // 0 = untraced (no wire field); nonzero travels flagged
                trace: if rng.chance(0.5) { rng.next_u64() | 1 } else { 0 },
                payload: random_bytes(rng, rows * n * dtype.size_bytes()),
            })
        }
        1 => {
            let dtype = random_dtype(rng);
            let n = 1 + rng.below(64);
            let rows = rng.below(4);
            let scales = match rng.below(3) {
                0 => QuantScales::None,
                1 => QuantScales::PerTensor(rng.normal_f32()),
                _ => QuantScales::PerGroup(
                    (0..rng.below(8)).map(|_| rng.normal_f32().abs()).collect(),
                ),
            };
            Frame::Response(WireResponse {
                id: rng.next_u64(),
                n: n as u32,
                rows: rows as u32,
                dtype,
                pjrt: rng.chance(0.5),
                batch_rows: rng.below(512) as u32,
                queue_us: rng.next_u64() >> 32,
                exec_us: rng.next_u64() >> 32,
                scales,
                payload: random_bytes(rng, rows * n * dtype.size_bytes()),
            })
        }
        2 => Frame::Error(WireError {
            id: rng.next_u64(),
            code: [
                ErrorCode::Malformed,
                ErrorCode::Rejected,
                ErrorCode::ExecFailed,
                ErrorCode::Draining,
            ][rng.below(4)],
            msg: random_string(rng, 100),
        }),
        3 => Frame::Busy {
            id: rng.next_u64(),
            retry_after_us: (rng.next_u64() & 0xffff_ffff) as u32,
        },
        4 => Frame::Ping { id: rng.next_u64() },
        5 => Frame::Pong { id: rng.next_u64() },
        6 => Frame::StatsRequest { id: rng.next_u64() },
        _ => Frame::Stats(WireStats {
            id: rng.next_u64(),
            counters: (0..rng.below(12))
                .map(|i| (format!("c{i}_{}", random_string(rng, 8)), rng.next_u64()))
                .collect(),
            report: random_string(rng, 200),
        }),
    }
}

#[test]
fn prop_roundtrip_arbitrary_frames() {
    check("wire roundtrip", 400, |rng| {
        let frame = random_frame(rng);
        let bytes = frame.encode();
        let (decoded, used) = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES)
            .expect("valid encoding must decode")
            .expect("complete encoding must yield a frame");
        assert_eq!(used, bytes.len(), "must consume exactly one frame");
        assert_eq!(decoded, frame);
    });
}

#[test]
fn prop_truncated_frames_are_incomplete_never_panic() {
    check("wire truncation", 300, |rng| {
        let bytes = random_frame(rng).encode();
        // a handful of random cut points plus the boundaries
        for _ in 0..8 {
            let cut = rng.below(bytes.len());
            let r = decode_frame(&bytes[..cut], DEFAULT_MAX_FRAME_BYTES);
            assert!(
                matches!(r, Ok(None)),
                "prefix of {cut}/{} bytes must be incomplete, got {r:?}",
                bytes.len()
            );
        }
    });
}

#[test]
fn prop_garbage_bytes_never_panic_or_overallocate() {
    // the decoder must stay total on arbitrary input: any outcome but a
    // panic. Run under a tiny frame cap so a random length prefix can't
    // even ask for a large body allocation.
    check("wire garbage", 400, |rng| {
        let soup = random_bytes(rng, rng.below(200));
        let _ = decode_frame(&soup, DEFAULT_MAX_FRAME_BYTES);
        let _ = decode_frame(&soup, 64);
        // body-level parser is total too
        let _ = parse_body(&soup);
    });
}

#[test]
fn prop_single_byte_corruption_never_panics() {
    check("wire corruption", 300, |rng| {
        let mut bytes = random_frame(rng).encode();
        let idx = rng.below(bytes.len());
        let flip = 1u8 << rng.below(8);
        bytes[idx] ^= flip;
        // any outcome but a panic; a corrupted length prefix may also
        // just look incomplete
        let _ = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES);
    });
}

#[test]
fn prop_streamed_frames_decode_in_sequence() {
    check("wire streaming", 150, |rng| {
        let frames: Vec<Frame> = (0..1 + rng.below(5)).map(|_| random_frame(rng)).collect();
        let mut buf = Vec::new();
        for f in &frames {
            buf.extend_from_slice(&f.encode());
        }
        let mut offset = 0;
        for want in &frames {
            let (got, used) = decode_frame(&buf[offset..], DEFAULT_MAX_FRAME_BYTES)
                .expect("stream decodes")
                .expect("complete frame");
            assert_eq!(&got, want);
            offset += used;
        }
        assert_eq!(offset, buf.len(), "stream fully consumed");
    });
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    // a length prefix beyond the cap errors immediately — even though the
    // buffer holds only 4 bytes, the decoder must not wait for (or try to
    // allocate) 4 GiB
    let mut buf = Vec::new();
    buf.extend_from_slice(&u32::MAX.to_le_bytes());
    let err = decode_frame(&buf, DEFAULT_MAX_FRAME_BYTES).unwrap_err();
    assert!(err.contains("exceeds cap"), "got: {err}");
}

#[test]
fn shape_payload_disagreement_is_malformed() {
    let mut r = WireRequest::from_f32(
        1,
        16,
        &vec![0.25f32; 32],
        KernelKind::HadaCore,
        DType::F32,
    );
    r.rows = 7; // payload carries 2 rows
    let err = decode_frame(&Frame::Request(r).encode(), DEFAULT_MAX_FRAME_BYTES)
        .unwrap_err();
    assert!(err.contains("payload"), "got: {err}");
}

#[test]
fn version_bump_is_rejected_with_a_named_error() {
    let mut bytes = Frame::Ping { id: 3 }.encode();
    bytes[4] = 2; // body[0] is the version
    let err = decode_frame(&bytes, DEFAULT_MAX_FRAME_BYTES).unwrap_err();
    assert!(err.contains("version"), "got: {err}");
}
