//! Buffer-pool lifecycle tests for the zero-copy serve path, plus the
//! zero-alloc steady-state gate (ISSUE 7).
//!
//! Leak detection: every request payload lands in a buffer borrowed from
//! the shared [`serve_pool`]; whatever happens to the request — normal
//! response, `Busy` shed, malformed-frame close, server teardown — the
//! buffer must come back (`outstanding() == 0`). The phases below share
//! one `#[test]` because the pool (and the allocation counters) are
//! process-global: concurrent tests would read each other's activity.
//!
//! Zero-alloc gate: with `--features count-alloc` this binary runs under
//! [`CountingAlloc`](hadacore::util::alloc::CountingAlloc); after a
//! warmup pass populates the pool shelves and per-thread scratch, a
//! traffic window over the serving stack must perform **zero** heap
//! allocations on tracked (server-side) threads. Without the feature the
//! alloc assertions are skipped (leak checks still run) — and
//! `is_counting()` makes that explicit rather than vacuously passing.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use hadacore::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, TransformRequest,
};
use hadacore::hadamard::{KernelKind, Prologue};
use hadacore::quant::{Epilogue, Fp8Format};
use hadacore::serve::wire::{decode_elems, encode_elems, WireRequest};
use hadacore::serve::{serve, Client, Reply, ServeConfig, ServeHandle};
use hadacore::util::alloc;
use hadacore::util::f16::DType;
use hadacore::util::pool::{serve_pool, BufferPool};
use hadacore::util::rng::Rng;

#[cfg(feature = "count-alloc")]
#[global_allocator]
static COUNTING_ALLOC: alloc::CountingAlloc = alloc::CountingAlloc;

/// Tests touching the process-global [`serve_pool`] (or the allocation
/// counters) must not overlap: the harness runs `#[test]`s on parallel
/// threads, and a concurrent server would hold pool buffers (and
/// allocate on tracked threads) right across another test's
/// `outstanding() == 0` and zero-alloc assertions.
static SERVE_POOL_LOCK: Mutex<()> = Mutex::new(());

fn serve_pool_guard() -> MutexGuard<'static, ()> {
    SERVE_POOL_LOCK
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn start_server(cfg: ServeConfig) -> (Arc<Coordinator>, ServeHandle) {
    let coord = Arc::new(
        Coordinator::start(
            None,
            CoordinatorConfig {
                workers: 2,
                batcher: BatcherConfig {
                    max_delay: Duration::from_micros(200),
                    work_conserving: true,
                },
                idle_timeout: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let handle = serve(Arc::clone(&coord), cfg).unwrap();
    (coord, handle)
}

fn quick_poll() -> ServeConfig {
    ServeConfig {
        poll_interval: Duration::from_millis(10),
        ..Default::default()
    }
}

/// The request shapes every phase drives: a latency-ish f32 shape, the
/// FP8 rotate→quantize epilogue, a 16-bit wire dtype (widen + narrow on
/// the same pooled buffer), a non-power-of-two size — and rotated
/// (sign-flip prologue) variants with **fixed seeds**, so the rotated
/// steady state exercises the process-wide `(seed, n)` sign-vector
/// cache: after warmup the fused prologue must cost zero allocations
/// per batch (the Arc is a cache hit, not a fresh materialisation).
fn shape_grid() -> Vec<(usize, usize, DType, Epilogue, Prologue)> {
    vec![
        (256, 2, DType::F32, Epilogue::None, Prologue::None),
        (1024, 4, DType::F32, Epilogue::None, Prologue::None),
        (
            1024,
            3,
            DType::F32,
            Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
            Prologue::None,
        ),
        (512, 2, DType::F16, Epilogue::None, Prologue::None),
        (768, 1, DType::F32, Epilogue::None, Prologue::None),
        // rotated workload: plain, rotate→quantize, and 16-bit widening
        (1024, 2, DType::F32, Epilogue::None, Prologue::SignFlip { seed: 0x5EED_0101 }),
        (
            768,
            2,
            DType::F32,
            Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
            Prologue::SignFlip { seed: 0x5EED_0202 },
        ),
        (512, 2, DType::F16, Epilogue::None, Prologue::SignFlip { seed: 0x5EED_0303 }),
        // grouped INT8: the per-response scale vector must come from
        // the scale recycler, not a fresh allocation per response
        (1024, 2, DType::F32, Epilogue::QuantInt8 { group: 64 }, Prologue::None),
        (
            512,
            4,
            DType::F32,
            Epilogue::QuantInt8 { group: 32 },
            Prologue::SignFlip { seed: 0x5EED_0404 },
        ),
    ]
}

fn make_wire(
    rng: &mut Rng,
    n: usize,
    rows: usize,
    dtype: DType,
    epilogue: Epilogue,
    prologue: Prologue,
) -> WireRequest {
    let data = rng.normal_vec(rows * n);
    let mut wire = WireRequest::from_f32(0, n, &data, KernelKind::HadaCore, dtype);
    wire.epilogue = epilogue;
    wire.prologue = prologue;
    wire
}

/// One pass over the shape grid; returns how many requests succeeded.
fn drive(client: &Client, rng: &mut Rng, passes: usize) -> usize {
    let mut ok = 0;
    for _ in 0..passes {
        for (n, rows, dtype, epilogue, prologue) in shape_grid() {
            let wire = make_wire(rng, n, rows, dtype, epilogue, prologue);
            let resp = client.transform(wire).expect("transform");
            assert_eq!(resp.rows as usize, rows);
            assert_eq!(resp.n as usize, n);
            ok += 1;
        }
    }
    ok
}

#[test]
fn serve_path_returns_every_pooled_buffer_and_hits_zero_allocs() {
    let _guard = serve_pool_guard();
    #[cfg(feature = "count-alloc")]
    alloc::mark_installed();
    let pool = serve_pool();
    let mut rng = Rng::new(0xA110C);

    // ---- phase A: normal traffic, then teardown -------------------------
    {
        let (coord, handle) = start_server(quick_poll());
        let client = Client::connect(&handle.addr().to_string()).unwrap();
        let ok = drive(&client, &mut rng, 4);
        assert!(ok >= 20);
        drop(client);
        handle.shutdown();
        coord.drain();
    }
    assert_eq!(
        pool.outstanding(),
        0,
        "phase A: every response must return its buffer to the pool"
    );

    // ---- phase B: admission shed + malformed frames ---------------------
    {
        // pipeline_depth 0 sheds *every* request deterministically: the
        // payload is already decoded into a pooled buffer by then, so
        // this exercises the drop-on-shed path
        let (coord, handle) = start_server(ServeConfig {
            pipeline_depth: 0,
            ..quick_poll()
        });
        let addr = handle.addr().to_string();
        let client = Client::connect(&addr).unwrap();
        for _ in 0..8 {
            let wire =
                make_wire(&mut rng, 256, 2, DType::F32, Epilogue::None, Prologue::None);
            match client.submit(wire).unwrap().wait() {
                Reply::Busy { retry_after_us } => assert!(retry_after_us > 0),
                other => panic!("pipeline_depth 0 must shed, got {other:?}"),
            }
        }
        drop(client);

        // a corrupt stream: the server answers Malformed and closes; any
        // buffered partial state must not pin pool buffers
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&[6, 0, 0, 0, 1, 0xEE, 0, 0, 0, 0]).unwrap();
        let mut sink = Vec::new();
        let _ = raw.read_to_end(&mut sink); // Error frame, then EOF
        assert!(!sink.is_empty(), "expected a Malformed error frame");

        // a partial request frame abandoned mid-stream (reader holds the
        // bytes, never completes the frame, connection closes)
        let wire = make_wire(&mut rng, 256, 1, DType::F32, Epilogue::None, Prologue::None);
        let bytes = hadacore::serve::wire::Frame::Request(wire).encode();
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&bytes[..bytes.len() / 2]).unwrap();
        drop(raw);

        handle.shutdown();
        coord.drain();
    }
    assert_eq!(
        pool.outstanding(),
        0,
        "phase B: shed and malformed paths must return buffers via RAII"
    );

    // ---- phase C: zero-alloc steady state -------------------------------
    {
        let (coord, handle) = start_server(quick_poll());
        let client = Client::connect(&handle.addr().to_string()).unwrap();
        // warmup: populate pool shelves, batcher spares, reply rings,
        // framer scratch, plan/tuning caches, and the (seed, n)
        // sign-vector cache for every shape measured — the rotated
        // entries must then be zero-alloc too (ISSUE 8 satellite)
        drive(&client, &mut rng, 6);

        let before = alloc::tracked();
        let ok = drive(&client, &mut rng, 8);
        let delta = alloc::tracked().since(before);

        if alloc::is_counting() {
            assert_eq!(
                delta.allocs, 0,
                "steady-state serve path allocated {} times ({} bytes) \
                 over {} requests",
                delta.allocs, delta.bytes, ok
            );
        } else {
            // without count-alloc the counters never move; make the
            // skipped assertion visible instead of vacuous
            assert_eq!(delta.allocs, 0);
            eprintln!(
                "count-alloc feature off: zero-alloc gate not measured \
                 (leak checks still ran)"
            );
        }
        drop(client);
        handle.shutdown();
        coord.drain();
    }
    assert_eq!(pool.outstanding(), 0, "phase C: teardown leaked buffers");
}

/// Hammer one pool from many threads: counts must balance and shelves
/// must absorb the churn without help from the global pool.
#[test]
fn pool_survives_concurrent_churn_without_leaks() {
    let pool = BufferPool::new(16);
    let threads: Vec<_> = (0..8)
        .map(|t| {
            let pool = pool.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xC0FFEE + t as u64);
                for i in 0..400 {
                    let elems = 64 + (rng.next_u64() as usize % 4096);
                    let mut buf = pool.get(elems);
                    buf.extend(std::iter::repeat(t as f32).take(elems));
                    assert!(buf.iter().all(|&v| v == t as f32));
                    if i % 7 == 0 {
                        // detach some buffers: into_vec must hand the
                        // allocation over without corrupting the counts
                        let v = buf.into_vec();
                        assert_eq!(v.len(), elems);
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(pool.outstanding(), 0, "all buffers must be back (or detached)");
}

/// TCP responses over the pooled zero-copy path must be byte-identical
/// to direct `Coordinator::submit` — the same guarantee `serve_e2e`
/// enforces, re-checked here against the canonical widened payload for
/// the shapes this suite drives.
#[test]
fn pooled_tcp_responses_match_direct_submit_bytes() {
    let _guard = serve_pool_guard();
    let (coord, handle) = start_server(quick_poll());
    let client = Client::connect(&handle.addr().to_string()).unwrap();
    let mut rng = Rng::new(0xB17E5);
    for (n, rows, dtype, epilogue, prologue) in shape_grid() {
        let wire = make_wire(&mut rng, n, rows, dtype, epilogue, prologue);
        // the server sees the *narrowed* payload: canonicalise through
        // the wire encoding before running the reference transform
        let canon = decode_elems(&wire.payload, dtype).unwrap();
        let resp = client.transform(wire).expect("transform");

        let mut direct = TransformRequest::new(0, n, canon);
        direct.kernel = KernelKind::HadaCore;
        direct.epilogue = epilogue;
        direct.prologue = prologue;
        let direct = coord.transform(direct).unwrap();

        assert_eq!(
            resp.payload,
            encode_elems(&direct.data, dtype),
            "n={n} rows={rows} {dtype:?}: pooled TCP payload diverged"
        );
        assert_eq!(resp.scales, direct.scales, "n={n}: scales diverged");
    }
    drop(client);
    handle.shutdown();
    coord.drain();
}
