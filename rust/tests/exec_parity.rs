//! Integration: the batched execution engine must be indistinguishable
//! from the single-call kernels — across kernels (scalar/dao/hadacore),
//! dtypes (f32/f16/bf16), the paper's size axis (256..32768) plus the
//! non-power-of-two `B * 2^k` sizes, chunk boundaries (rows not
//! divisible by the chunk height, single-row batches), and lane counts
//! (1, 3, 8).
//!
//! Two bars:
//! * **bit-for-bit vs the direct call of the same kernel** — sharding by
//!   rows must not change a single ULP (rows are independent, and the
//!   planned HadaCore path replays the exact pass structure);
//! * **close to the scalar oracle** — the cross-kernel accuracy bar every
//!   kernel already meets in unit tests, re-checked through the engine.

use hadacore::exec::{ExecConfig, ExecEngine, TunePolicy};
use hadacore::hadamard::{fwht_f32, fwht_generic, FwhtOptions, KernelKind};
use hadacore::util::f16::{Element, BF16, F16};
use hadacore::util::prop::assert_close;
use hadacore::util::rng::Rng;

/// Lane configurations under test: no pool, an odd lane count, a
/// deliberately aggressive sharder (tiny chunks => many boundaries),
/// and every pinned round-fusion depth (the autotuned fused path must
/// be indistinguishable from the unfused one — this is the acceptance
/// grid for the fusion tentpole; depth 4 exceeds every plan's round
/// count and must clamp).
fn engines() -> Vec<(&'static str, ExecEngine)> {
    let mut v = vec![
        ("t1", ExecEngine::single_threaded()),
        (
            "t3",
            ExecEngine::new(ExecConfig {
                threads: 3,
                chunks_per_thread: 2,
                min_chunk_elems: 4096,
                ..ExecConfig::default()
            }),
        ),
        (
            "t8-fine",
            ExecEngine::new(ExecConfig {
                threads: 8,
                chunks_per_thread: 4,
                min_chunk_elems: 256,
                ..ExecConfig::default()
            }),
        ),
        (
            "t1-untuned",
            ExecEngine::new(ExecConfig {
                threads: 1,
                tune: TunePolicy::Off,
                ..ExecConfig::default()
            }),
        ),
    ];
    for (name, depth) in
        [("t4-d1", 1usize), ("t4-d2", 2), ("t4-d3", 3), ("t4-d4", 4)]
    {
        v.push((
            name,
            ExecEngine::new(ExecConfig {
                threads: 4,
                chunks_per_thread: 2,
                min_chunk_elems: 1024,
                tune: TunePolicy::FixedDepth(depth),
            }),
        ));
    }
    v
}

/// (n, rows) grid: paper sizes with row counts chosen to not divide
/// evenly into chunks, plus single-row batches, plus the non-power-of-two
/// `B * 2^k` family (12·64, 20·256, 28·512 — the Llama-3 FFN dim).
const SHAPES: [(usize, usize); 11] = [
    (256, 1),
    (256, 67),
    (512, 33),
    (768, 33),
    (1024, 13),
    (4096, 9),
    (4096, 1),
    (5120, 9),
    (14336, 3),
    (16384, 5),
    (32768, 3),
];

fn scalar_oracle(x: &[f32], n: usize, opts: &FwhtOptions) -> Vec<f32> {
    let mut want = x.to_vec();
    fwht_f32(KernelKind::Scalar, &mut want, n, opts);
    want
}

#[test]
fn f32_engine_matches_direct_and_oracle() {
    let mut rng = Rng::new(0xE0);
    for (label, engine) in engines() {
        for &(n, rows) in &SHAPES {
            let x = rng.normal_vec(rows * n);
            let opts = FwhtOptions::normalized(n);
            let oracle = scalar_oracle(&x, n, &opts);
            for kind in KernelKind::all() {
                let mut direct = x.clone();
                fwht_f32(kind, &mut direct, n, &opts);
                let mut sharded = x.clone();
                engine.run_f32(kind, &mut sharded, n, &opts);
                assert_eq!(
                    direct, sharded,
                    "bit drift: engine={label} kind={kind:?} n={n} rows={rows}"
                );
                assert_close(&sharded, &oracle, 1e-3, 1e-3);
            }
        }
    }
}

#[test]
fn f16_engine_matches_direct_and_oracle() {
    let mut rng = Rng::new(0xE1);
    for (label, engine) in engines() {
        for &(n, rows) in &SHAPES {
            let x = rng.normal_vec(rows * n);
            let base: Vec<F16> = x.iter().map(|&v| F16::from_f32(v)).collect();
            let opts = FwhtOptions::normalized(n);
            for kind in KernelKind::all() {
                let mut direct = base.clone();
                fwht_generic(kind, &mut direct, n, &opts);
                let mut sharded = base.clone();
                engine.run(kind, &mut sharded, n, &opts);
                assert_eq!(
                    direct, sharded,
                    "bit drift: engine={label} kind={kind:?} n={n} rows={rows}"
                );
            }
            // accuracy bar vs the f32 scalar oracle, at f16 tolerance
            let widened: Vec<f32> = x.iter().map(|&v| F16::from_f32(v).to_f32()).collect();
            let oracle = scalar_oracle(&widened, n, &opts);
            let mut sharded = base.clone();
            engine.run(KernelKind::HadaCore, &mut sharded, n, &opts);
            let got: Vec<f32> = sharded.iter().map(|v| v.to_f32()).collect();
            assert_close(&got, &oracle, 2e-2, 2e-2);
        }
    }
}

#[test]
fn bf16_engine_matches_direct() {
    let mut rng = Rng::new(0xE2);
    for (label, engine) in engines() {
        for &(n, rows) in &[(512usize, 33usize), (4096, 9), (32768, 3)] {
            let x = rng.normal_vec(rows * n);
            let base: Vec<BF16> = x.iter().map(|&v| BF16::from_f32(v)).collect();
            let opts = FwhtOptions::normalized(n);
            for kind in KernelKind::all() {
                let mut direct = base.clone();
                fwht_generic(kind, &mut direct, n, &opts);
                let mut sharded = base.clone();
                engine.run(kind, &mut sharded, n, &opts);
                assert_eq!(
                    direct, sharded,
                    "bit drift: engine={label} kind={kind:?} n={n} rows={rows}"
                );
            }
        }
    }
}

#[test]
fn repeated_batches_stop_allocating() {
    // steady-state zero-allocation on the 16-bit path: workspace growth is
    // bounded by the lane count, not the batch count
    let engine = ExecEngine::new(ExecConfig {
        threads: 4,
        chunks_per_thread: 2,
        min_chunk_elems: 1024,
        ..ExecConfig::default()
    });
    let mut rng = Rng::new(0xE3);
    let (rows, n) = (64usize, 1024usize);
    let base: Vec<BF16> = rng
        .normal_vec(rows * n)
        .iter()
        .map(|&v| BF16::from_f32(v))
        .collect();
    let opts = FwhtOptions::normalized(n);
    for _ in 0..50 {
        let mut batch = base.clone();
        engine.run(KernelKind::HadaCore, &mut batch, n, &opts);
    }
    let s = engine.stats();
    assert!(s.jobs == 50, "all batches should shard: {s:?}");
    assert!(
        s.scratch_grows <= 4,
        "16-bit path must reuse per-thread workspaces: {s:?}"
    );
}

#[test]
fn custom_scales_shard_correctly() {
    // the per-element scale must be applied exactly once per element no
    // matter how the rows are chunked
    let engine = ExecEngine::new(ExecConfig {
        threads: 8,
        chunks_per_thread: 4,
        min_chunk_elems: 256,
        ..ExecConfig::default()
    });
    let n = 512;
    let rows = 29;
    let mut data = vec![1.0f32; rows * n];
    engine.run_f32(
        KernelKind::HadaCore,
        &mut data,
        n,
        &FwhtOptions::with_scale(0.125),
    );
    for r in 0..rows {
        let row = &data[r * n..(r + 1) * n];
        assert!((row[0] - n as f32 * 0.125).abs() < 1e-2, "row {r}: {}", row[0]);
        assert!(row[1..].iter().all(|v| v.abs() < 1e-3), "row {r} leakage");
    }
}
