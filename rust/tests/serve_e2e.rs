//! Loopback end-to-end tests of the TCP serving layer — the `serve-e2e`
//! CI gate.
//!
//! Acceptance contract (ISSUE 5):
//!
//! * concurrent pipelining clients through the TCP server receive
//!   responses **bit-identical** to direct `Coordinator::submit` for
//!   every kernel × dtype × epilogue combination tested (mixed sizes
//!   including the non-power-of-two 14336 = 28·512);
//! * overload answers a retriable `Busy` frame — no hang, no dropped
//!   connection;
//! * server teardown + `Coordinator::drain` complete in-flight requests
//!   instead of erroring them.

use std::sync::Arc;
use std::time::Duration;

use hadacore::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, TransformRequest,
};
use hadacore::hadamard::KernelKind;
use hadacore::quant::{Epilogue, Fp8Format};
use hadacore::serve::wire::{decode_elems, encode_elems, ErrorCode, WireRequest};
use hadacore::serve::{serve, Client, Reply, ServeConfig, ServeHandle};
use hadacore::util::f16::DType;
use hadacore::util::rng::Rng;

fn start_coordinator(workers: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(
            None,
            CoordinatorConfig {
                workers,
                batcher: BatcherConfig {
                    max_delay: Duration::from_micros(200),
                    work_conserving: true,
                },
                idle_timeout: Duration::from_millis(10),
                ..Default::default()
            },
        )
        .unwrap(),
    )
}

fn start_server(workers: usize, cfg: ServeConfig) -> (Arc<Coordinator>, ServeHandle) {
    let coord = start_coordinator(workers);
    let handle = serve(Arc::clone(&coord), cfg).unwrap();
    (coord, handle)
}

fn quick_poll() -> ServeConfig {
    ServeConfig {
        poll_interval: Duration::from_millis(10),
        ..Default::default()
    }
}

/// One test case of the kernel × dtype × epilogue grid.
#[derive(Clone)]
struct Case {
    n: usize,
    rows: usize,
    kernel: KernelKind,
    dtype: DType,
    epilogue: Epilogue,
    seed: u64,
}

fn case_grid() -> Vec<Case> {
    let mut cases = Vec::new();
    let mut seed = 0x5EED;
    // f32 over the full size mix (incl. npot 768 and 14336 = 28*512),
    // both fast kernels, all three epilogues
    for &n in &[256usize, 768, 1024, 4096, 14336] {
        for &kernel in &[KernelKind::HadaCore, KernelKind::Dao] {
            for epilogue in [
                Epilogue::None,
                Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
                Epilogue::QuantInt8 { group: 64 },
            ] {
                seed += 1;
                cases.push(Case {
                    n,
                    rows: 1 + (seed as usize % 3),
                    kernel,
                    dtype: DType::F32,
                    epilogue,
                    seed,
                });
            }
        }
    }
    // 16-bit wire dtypes (payloads canonicalise through narrow->widen)
    for &dtype in &[DType::F16, DType::BF16] {
        for &n in &[512usize, 14336] {
            seed += 1;
            cases.push(Case {
                n,
                rows: 2,
                kernel: KernelKind::HadaCore,
                dtype,
                epilogue: Epilogue::None,
                seed,
            });
        }
    }
    // the scalar oracle rides along once
    cases.push(Case {
        n: 2048,
        rows: 2,
        kernel: KernelKind::Scalar,
        dtype: DType::F32,
        epilogue: Epilogue::None,
        seed: 0x0C0DE,
    });
    cases
}

/// The canonical f32 payload a case's wire bytes decode to on the server.
fn canonical_payload(case: &Case) -> Vec<f32> {
    let mut rng = Rng::new(case.seed);
    let raw = rng.normal_vec(case.rows * case.n);
    decode_elems(&encode_elems(&raw, case.dtype), case.dtype).unwrap()
}

#[test]
fn concurrent_pipelining_clients_bit_identical_to_direct_submit() {
    let (coord, handle) = start_server(4, quick_poll());
    let addr = handle.addr().to_string();
    let cases = case_grid();
    assert!(cases.len() >= 30, "grid must stay meaningful");

    // >= 8 concurrent clients, each pipelining its whole slice of the
    // grid before collecting any reply
    let n_clients = 8;
    let mut threads = Vec::new();
    for t in 0..n_clients {
        let addr = addr.clone();
        let coord = Arc::clone(&coord);
        let slice: Vec<Case> = cases
            .iter()
            .skip(t)
            .step_by(n_clients)
            .cloned()
            .collect();
        threads.push(std::thread::spawn(move || {
            let client = Client::connect(&addr).unwrap();
            let mut pending = Vec::new();
            for case in &slice {
                let data = canonical_payload(case);
                let mut wire = WireRequest::from_f32(
                    0, case.n, &data, case.kernel, case.dtype,
                );
                wire.epilogue = case.epilogue;
                pending.push(client.submit(wire).unwrap());
            }
            for (case, p) in slice.iter().zip(pending) {
                let resp = match p.wait() {
                    Reply::Response(r) => r,
                    other => panic!(
                        "case n={} {:?} {:?}: unexpected reply {other:?}",
                        case.n, case.kernel, case.epilogue
                    ),
                };
                // direct submit of the identical canonical payload
                // through the very same coordinator
                let mut req =
                    TransformRequest::new(1, case.n, canonical_payload(case));
                req.kernel = case.kernel;
                req.epilogue = case.epilogue;
                let direct = coord.transform(req).unwrap();

                assert_eq!(
                    resp.payload,
                    encode_elems(&direct.data, case.dtype),
                    "case n={} {:?} {:?} {:?}: wire bytes must be \
                     bit-identical to direct submit",
                    case.n,
                    case.kernel,
                    case.dtype,
                    case.epilogue
                );
                assert_eq!(
                    resp.scales, direct.scales,
                    "case n={}: epilogue scales must match",
                    case.n
                );
                assert_eq!(resp.n as usize, case.n);
                assert_eq!(resp.rows as usize, case.rows);
                assert_eq!(resp.backend(), "native");
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.failed, 0, "no request may fail: {}", snap.report());
    handle.shutdown();
    coord.drain();
}

#[test]
fn responses_stream_back_out_of_order() {
    let (coord, handle) = start_server(4, quick_poll());
    let client = Client::connect(&handle.addr().to_string()).unwrap();

    // a slow scalar batch, then a fast hadacore one, pipelined
    let slow_data = vec![1.0f32; 8 * 16384];
    let mut slow = WireRequest::from_f32(
        0, 16384, &slow_data, KernelKind::Scalar, DType::F32,
    );
    slow.force_native = true;
    let slow_pending = client.submit(slow).unwrap();

    let fast_data = vec![1.0f32; 128];
    let fast = WireRequest::from_f32(0, 128, &fast_data, KernelKind::HadaCore, DType::F32);
    let fast_pending = client.submit(fast).unwrap();

    // the fast response must arrive while the slow one is still pending
    let mut fast_first = false;
    for _ in 0..2000 {
        if fast_pending.try_wait().is_some() {
            fast_first = slow_pending.try_wait().is_none();
            break;
        }
        std::thread::sleep(Duration::from_micros(100));
    }
    // the slow response still arrives fine afterwards
    assert!(matches!(slow_pending.wait(), Reply::Response(_)));
    assert!(
        fast_first,
        "the fast pipelined response must overtake the slow one"
    );
    drop(client);
    handle.shutdown();
    coord.drain();
}

#[test]
fn pipeline_cap_sheds_with_retriable_busy_and_no_hang() {
    let (coord, handle) = start_server(
        2,
        ServeConfig {
            pipeline_depth: 1,
            busy_retry_us: 250,
            poll_interval: Duration::from_millis(10),
            ..Default::default()
        },
    );
    let client = Client::connect(&handle.addr().to_string()).unwrap();

    // one slow request occupies the whole pipeline window...
    let slow_data = vec![1.0f32; 16 * 32768];
    let mut slow = WireRequest::from_f32(
        0, 32768, &slow_data, KernelKind::Scalar, DType::F32,
    );
    slow.force_native = true;
    let slow_pending = client.submit(slow).unwrap();

    // ...so rapid-fire follow-ups shed with Busy (retriable: the
    // connection stays open, every reply arrives, nothing hangs)
    let mut busy = 0;
    let mut ok = 0;
    let mut followups = Vec::new();
    for _ in 0..5 {
        let data = vec![1.0f32; 256];
        let req = WireRequest::from_f32(0, 256, &data, KernelKind::HadaCore, DType::F32);
        followups.push(client.submit(req).unwrap());
    }
    for p in followups {
        match p.wait() {
            Reply::Busy { retry_after_us } => {
                assert_eq!(retry_after_us, 250, "busy carries the retry hint");
                busy += 1;
            }
            Reply::Response(_) => ok += 1,
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(busy >= 1, "at least one follow-up must shed (got {ok} ok)");
    assert!(matches!(slow_pending.wait(), Reply::Response(_)));

    // the shed was load control, not a failure: the connection still
    // serves once the window frees up
    let data = vec![0.5f32; 512];
    let req = WireRequest::from_f32(0, 512, &data, KernelKind::HadaCore, DType::F32);
    let resp = client.transform(req).unwrap();
    assert_eq!(resp.rows, 1);
    assert!(handle.counters().busy_shed.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    drop(client);
    handle.shutdown();
    coord.drain();
}

#[test]
fn queue_depth_shedding_answers_busy() {
    // one batcher worker + zero-tolerance queue depth: while the worker
    // chews a slow batch and a second slow batch waits in the batcher,
    // new arrivals shed on the queue-depth signal
    let (coord, handle) = start_server(
        1,
        ServeConfig {
            max_queued_rows: 0,
            poll_interval: Duration::from_millis(10),
            ..Default::default()
        },
    );
    let client = Client::connect(&handle.addr().to_string()).unwrap();

    // distinct sizes => distinct batcher buckets, so the two slow
    // requests can never merge into one batch: whichever the single
    // worker picks first, the other stays *queued* while it executes
    let slow_a = vec![1.0f32; 8 * 32768];
    let mut first_req = WireRequest::from_f32(
        0, 32768, &slow_a, KernelKind::Scalar, DType::F32,
    );
    first_req.force_native = true;
    let slow_b = vec![1.0f32; 16 * 16384];
    let mut second_req = WireRequest::from_f32(
        0, 16384, &slow_b, KernelKind::Scalar, DType::F32,
    );
    second_req.force_native = true;
    let first = client.submit(first_req).unwrap();
    let second = client.submit(second_req).unwrap();

    let mut busy = 0;
    let mut followups = Vec::new();
    for _ in 0..5 {
        let data = vec![1.0f32; 256];
        followups.push(
            client
                .submit(WireRequest::from_f32(0, 256, &data, KernelKind::HadaCore, DType::F32))
                .unwrap(),
        );
    }
    for p in followups {
        match p.wait() {
            Reply::Busy { .. } => busy += 1,
            Reply::Response(_) => {}
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(busy >= 1, "queued rows must trigger shedding");
    assert!(matches!(first.wait(), Reply::Response(_)));
    assert!(matches!(second.wait(), Reply::Response(_)));
    drop(client);
    handle.shutdown();
    coord.drain();
}

#[test]
fn teardown_completes_inflight_and_rejects_late_requests() {
    let (coord, handle) = start_server(2, quick_poll());
    let client = Client::connect(&handle.addr().to_string()).unwrap();

    let mut rng = Rng::new(77);
    let mut pending = Vec::new();
    for i in 0..20 {
        let n = [256usize, 1024, 14336][i % 3];
        let data = rng.normal_vec(n);
        pending.push(
            client
                .submit(WireRequest::from_f32(0, n, &data, KernelKind::HadaCore, DType::F32))
                .unwrap(),
        );
    }
    // let the reader admit at least the head of the pipeline, then tear
    // down mid-traffic: front-end first, then the coordinator
    std::thread::sleep(Duration::from_millis(15));
    handle.shutdown();
    coord.drain();

    let mut responses = 0;
    let mut draining = 0;
    for p in pending {
        match p.wait() {
            Reply::Response(_) => responses += 1,
            Reply::Error { code: ErrorCode::Draining, .. } => draining += 1,
            Reply::Disconnected => draining += 1, // raced the close
            other => panic!("unexpected teardown reply {other:?}"),
        }
    }
    assert_eq!(responses + draining, 20, "every request resolves — no hang");
    assert!(responses >= 1, "in-flight requests complete, not error");

    // the coordinator now refuses work with a retriable message
    let err = coord
        .submit(TransformRequest::new(1, 256, vec![0.0; 256]))
        .unwrap_err();
    assert!(err.0.contains("draining"));
}

#[test]
fn shutdown_returns_even_while_a_client_keeps_streaming() {
    use std::sync::atomic::{AtomicBool, Ordering};
    let (coord, handle) = start_server(2, quick_poll());
    let addr = handle.addr().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    // a client that keeps frames flowing faster than the poll quantum
    let pinger = std::thread::spawn(move || {
        let client = Client::connect(&addr).unwrap();
        while !stop2.load(Ordering::Relaxed) {
            if client.ping().is_err() {
                break; // the server went away: done
            }
        }
    });
    std::thread::sleep(Duration::from_millis(50)); // let traffic flow
    let t0 = std::time::Instant::now();
    handle.shutdown();
    coord.drain();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "teardown must not be pinned open by a streaming client"
    );
    stop.store(true, Ordering::Relaxed);
    let _ = pinger.join();
}

#[test]
fn submits_after_disconnect_fail_fast_instead_of_hanging() {
    let (coord, handle) = start_server(2, quick_poll());
    let client = Client::connect(&handle.addr().to_string()).unwrap();
    let data = vec![1.0f32; 256];
    client
        .transform(WireRequest::from_f32(0, 256, &data, KernelKind::HadaCore, DType::F32))
        .unwrap();

    // the server goes away; the client's reader observes the close and
    // marks the connection dead
    handle.shutdown();
    coord.drain();
    let t0 = std::time::Instant::now();
    while !client.is_dead() && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(client.is_dead(), "reader must notice the closed connection");

    // a submit now errors immediately — it must never register a waiter
    // that nothing can resolve
    let err = client
        .submit(WireRequest::from_f32(0, 256, &data, KernelKind::HadaCore, DType::F32))
        .unwrap_err();
    assert!(err.to_string().contains("closed"), "got: {err}");
}

#[test]
fn responses_that_cannot_fit_the_frame_cap_are_rejected_not_fatal() {
    // a tiny server-side frame cap: a request whose *reply* (payload +
    // int8 per-group scales) would overflow it is rejected with a named
    // error, instead of the server emitting a frame the client's
    // decoder would treat as a corrupt stream
    let (coord, handle) = start_server(
        2,
        ServeConfig {
            max_frame_bytes: 8192,
            poll_interval: Duration::from_millis(10),
            ..Default::default()
        },
    );
    let client = Client::connect(&handle.addr().to_string()).unwrap();
    let data = vec![1.0f32; 1024];

    // group=1 doubles the reply size: 4 KiB payload + 4 KiB scales > cap
    let mut big_reply =
        WireRequest::from_f32(0, 1024, &data, KernelKind::HadaCore, DType::F32);
    big_reply.epilogue = Epilogue::QuantInt8 { group: 1 };
    match client.submit(big_reply).unwrap().wait() {
        Reply::Error { code: ErrorCode::Rejected, msg } => {
            assert!(msg.contains("frame cap"), "got: {msg}");
        }
        other => panic!("want a rejection, got {other:?}"),
    }

    // the same shape without the scale blow-up fits and still serves
    let ok = client
        .transform(WireRequest::from_f32(0, 1024, &data, KernelKind::HadaCore, DType::F32))
        .unwrap();
    assert_eq!(ok.n, 1024);
    drop(client);
    handle.shutdown();
    coord.drain();
}

#[test]
fn stats_and_ping_frames() {
    let (coord, handle) = start_server(2, quick_poll());
    let client = Client::connect(&handle.addr().to_string()).unwrap();

    for _ in 0..5 {
        let data = vec![1.0f32; 512];
        client
            .transform(WireRequest::from_f32(0, 512, &data, KernelKind::HadaCore, DType::F32))
            .unwrap();
    }
    let rtt = client.ping().unwrap();
    assert!(rtt < Duration::from_secs(5));

    let stats = client.stats().unwrap();
    let get = |k: &str| {
        stats
            .counters
            .iter()
            .find(|(key, _)| key == k)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("stats must carry '{k}'"))
    };
    assert!(get("submitted") >= 5);
    assert!(get("completed") >= 5);
    assert_eq!(get("conns_active"), 1);
    assert!(get("requests") >= 5);
    // the text report carries the histogram percentile reconstruction
    assert!(stats.report.contains("p50"), "got: {}", stats.report);
    assert!(stats.report.contains("p90"), "got: {}", stats.report);
    assert!(stats.report.contains("serve:"), "got: {}", stats.report);
    drop(client);
    handle.shutdown();
    coord.drain();
}

#[test]
fn malformed_frames_get_error_replies_and_the_server_survives() {
    use std::io::{Read, Write};
    let (coord, handle) = start_server(2, quick_poll());
    let addr = handle.addr();

    // hand-written garbage: valid length prefix, bogus version byte
    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    let body = [9u8, 1, 0, 0, 0, 0, 0, 0]; // version 9
    raw.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    raw.write_all(&body).unwrap();
    // the server answers a Malformed error frame, then closes
    let mut reply = Vec::new();
    raw.read_to_end(&mut reply).unwrap();
    let (frame, _) = hadacore::serve::wire::decode_frame(
        &reply,
        hadacore::serve::wire::DEFAULT_MAX_FRAME_BYTES,
    )
    .unwrap()
    .expect("server must answer before closing");
    match frame {
        hadacore::serve::wire::Frame::Error(e) => {
            assert_eq!(e.code, ErrorCode::Malformed);
            assert!(e.msg.contains("version"), "got: {}", e.msg);
        }
        other => panic!("want error frame, got {other:?}"),
    }

    // an oversized length prefix is also answered + closed, not honoured
    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    raw.write_all(&u32::MAX.to_le_bytes()).unwrap();
    let mut reply = Vec::new();
    raw.read_to_end(&mut reply).unwrap();
    assert!(!reply.is_empty(), "oversized frames get an error reply");

    // the server is still healthy for well-behaved clients
    let client = Client::connect(&addr.to_string()).unwrap();
    let data = vec![1.0f32; 256];
    let resp = client
        .transform(WireRequest::from_f32(0, 256, &data, KernelKind::HadaCore, DType::F32))
        .unwrap();
    assert_eq!(resp.rows, 1);
    assert!(
        handle
            .counters()
            .protocol_errors
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 2
    );
    drop(client);
    handle.shutdown();
    coord.drain();
}

#[test]
fn loadgen_smoke_end_to_end_with_bench_emission() {
    use hadacore::harness::workload::traffic_mix;
    use hadacore::serve::loadgen::{run, LoadgenConfig};
    use hadacore::util::bench::{validate_bench_json, BenchJson};

    let (coord, handle) = start_server(2, quick_poll());
    let cfg = LoadgenConfig {
        addr: handle.addr().to_string(),
        mix: "mixed".to_string(),
        workload: traffic_mix("mixed").unwrap(),
        qps: 0.0, // unpaced smoke
        requests: 60,
        clients: 2,
        dtype: DType::F32,
        ..Default::default()
    };
    let report = run(&cfg).unwrap();
    assert_eq!(report.sent, 60);
    assert_eq!(
        report.ok + report.busy + report.errors + report.disconnects,
        report.sent,
        "every request resolves exactly once"
    );
    assert!(report.ok > 0, "smoke must complete work: {}", report.line());
    assert!(report.achieved_qps > 0.0);
    assert_eq!(report.latencies_us.len() as u64, report.ok);

    // the perf-trajectory emission validates against hadacore-bench-v1
    let mut out = BenchJson::new();
    out.push(report.to_record(&cfg));
    let path = std::env::temp_dir()
        .join(format!("hc_pr5_smoke_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    assert_eq!(out.write(&path).unwrap(), 1);
    assert_eq!(validate_bench_json(&path).unwrap(), 1);
    std::fs::remove_file(&path).ok();

    handle.shutdown();
    coord.drain();
}
