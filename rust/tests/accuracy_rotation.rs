//! Paper §4.2 accuracy claim, tensor level: at the Llama dims, the
//! randomized Hadamard rotation must **raise the quantised pipeline's
//! SNR** on outlier-heavy activations — with rotation ≥ without, at
//! n ∈ {4096, 14336}, for every quantisation scheme in the study.
//!
//! ## Threshold derivation (why these exact bounds)
//!
//! For per-row absmax quantisation on a `b`-bit-equivalent grid, the
//! signal-to-quantisation-noise ratio is approximately
//! `SNR_dB ≈ 4.77 + 6.02·b − 20·log10(amax / rms)` — the last term is
//! the incoherence penalty: scale wasted on the dynamic range between
//! the largest coordinate and the typical one.
//!
//! The study's payloads (`outlier_activations`, scale 48 on the 6
//! `OUTLIER_CHANNELS`) put `amax ≈ 48·E[max of ~6·rows normals] ≈ 120`
//! over `rms ≈ sqrt(1 + 6·(48²−1)/n)` ≈ 2.1 at n = 4096, ≈ 1.4 at
//! n = 14336, so the unrotated penalty is ≈ 35–39 dB. After the
//! rotation every coordinate is a ±-signed average of the whole row, so
//! `amax` falls to the Gaussian-max level `rms·sqrt(2·ln(2n))` ≈ 4·rms
//! and the penalty to ≈ 12–13 dB: an expected SNR gain of **≈ 20 dB or
//! more** at both dims, for both fp8 and int8 (`b` cancels in the
//! difference).
//!
//! Gates, with ≈ 3× headroom on the model (the matmul-proxy mixing and
//! multi-layer accumulation shave a few dB, and per-cell noise is real):
//!
//! * every (plain, rotated) pair: gain > 0 dB  (the claim itself), and
//! * the median gain over all cells ≥ 6 dB  (a sign-test-style gate
//!   that the effect is the predicted *large* one, not a lucky zero).
//!
//! Non-vacuity: the plain pipeline must actually lose information
//! (SNR below the exactness clamp), and the payload generator must
//! actually concentrate amax in the outlier channels — otherwise every
//! gate above could pass on a degenerate study.

use hadacore::exec::ExecEngine;
use hadacore::hadamard::KernelKind;
use hadacore::harness::accuracy::{
    outlier_activations, run_study, StudyConfig, OUTLIER_CHANNELS, SNR_CLAMP_DB,
};
use hadacore::quant::Scheme;
use hadacore::util::f16::DType;
use hadacore::util::rng::Rng;

/// The two Llama dims named by the acceptance criteria: hidden (4096)
/// and FFN (14336 = 28·512, non-power-of-two).
const DIMS: [usize; 2] = [4096, 14336];

fn study_config() -> StudyConfig {
    StudyConfig {
        sizes: DIMS.to_vec(),
        rows: 8,
        layers: 2,
        kernels: vec![KernelKind::HadaCore],
        dtypes: vec![DType::F32, DType::BF16],
        schemes: vec![Scheme::Fp8E4m3, Scheme::Int8],
        outlier_scale: 48.0,
        seed: 0x5EED_0ACC,
    }
}

#[test]
fn rotation_raises_quant_snr_at_llama_dims() {
    let records = run_study(&ExecEngine::default(), &study_config());
    assert!(!records.is_empty());
    assert_eq!(records.len() % 2, 0, "records must arrive in (plain, rotated) pairs");

    let mut seen_dims = [false; 2];
    let mut gains: Vec<f64> = Vec::new();
    for pair in records.chunks_exact(2) {
        let (plain, rotated) = (&pair[0], &pair[1]);
        assert!(!plain.rotated && rotated.rotated, "pair ordering broke");
        assert_eq!(plain.n, rotated.n);
        assert_eq!(plain.scheme, rotated.scheme);
        if let Some(i) = DIMS.iter().position(|&d| d == plain.n) {
            seen_dims[i] = true;
        }

        // non-vacuity: quantisation must actually be lossy in the plain
        // pipeline (an exact pipeline clamps at SNR_CLAMP_DB and would
        // make "rotated >= plain" meaningless)
        assert!(
            plain.snr_db < SNR_CLAMP_DB,
            "{} n={} {}: plain pipeline is lossless — study is vacuous",
            plain.dtype,
            plain.n,
            plain.scheme
        );
        assert!(plain.snr_db.is_finite() && rotated.snr_db.is_finite());

        let gain = rotated.snr_db - plain.snr_db;
        assert!(
            gain > 0.0,
            "{} n={} {}: rotation lowered SNR ({:.2} dB -> {:.2} dB)",
            plain.dtype,
            plain.n,
            plain.scheme,
            plain.snr_db,
            rotated.snr_db
        );
        gains.push(gain);
    }
    assert!(seen_dims.iter().all(|&s| s), "study must cover n = 4096 and n = 14336");

    // the effect must be the predicted large one, not a lucky epsilon
    gains.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = gains[gains.len() / 2];
    assert!(
        median >= 6.0,
        "median rotation gain {median:.2} dB below the derived 6 dB floor \
         (model predicts ~20 dB; see module header)"
    );
}

#[test]
fn outlier_payload_concentrates_amax_in_the_outlier_channels() {
    // non-vacuity for the whole study: the synthetic activations must
    // be genuinely outlier-heavy, i.e. the amax the quantiser pays for
    // sits in OUTLIER_CHANNELS and dwarfs the bulk — otherwise the
    // rotation would have nothing to fix and the gates above would be
    // testing noise
    for n in DIMS {
        let mut rng = Rng::new(0x0AC5);
        let rows = 8;
        let x = outlier_activations(&mut rng, rows, n, 48.0);
        assert_eq!(x.len(), rows * n);
        let mut amax_outlier = 0.0f32;
        let mut amax_rest = 0.0f32;
        for (i, v) in x.iter().enumerate() {
            if OUTLIER_CHANNELS.contains(&(i % n)) {
                amax_outlier = amax_outlier.max(v.abs());
            } else {
                amax_rest = amax_rest.max(v.abs());
            }
        }
        assert!(
            amax_outlier >= 10.0 * amax_rest,
            "n={n}: outlier channels carry amax {amax_outlier:.2} vs bulk \
             {amax_rest:.2} — payload is not outlier-heavy"
        );
    }
}
