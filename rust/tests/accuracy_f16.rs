//! Paper §4.2 numerics: 16-bit transform accuracy against the dense f32
//! reference (`matvec_hadamard_n`), across the supported size family.
//!
//! ## Threshold derivation (why these exact bounds)
//!
//! The 16-bit path computes: narrow input to E ∈ {f16, bf16} (exact —
//! the inputs below are already E-representable), widen to f32 (exact),
//! transform in f32, narrow the result once with round-to-nearest-even.
//! Against the dense reference on the *same widened inputs* the error
//! has two parts:
//!
//! 1. **f32 compute error**: the FWHT is `log2(n)` levels of adds/subs;
//!    with the orthonormal scale each output is an average of `n` inputs
//!    with ±1 signs, so the accumulated relative error is
//!    ≤ ~`log2(n) · 2^-24` — at n = 16384 that is ~6e-7, two orders of
//!    magnitude below either storage format's rounding step. Negligible.
//! 2. **the final narrowing**: one round-to-nearest-even, bounded by
//!    half an ULP of the result — relative error ≤ `2^-11` for f16
//!    (10 fraction bits) and ≤ `2^-8` for bf16 (7 fraction bits).
//!
//! Individual outputs can be arbitrarily close to zero (cancellation),
//! where *pointwise* relative error is meaningless — the standard
//! metric (Markidis et al.'s tensor-core precision methodology) is the
//! max absolute error **relative to the output's max magnitude**, whose
//! narrowing bound is the same half-ULP-at-amax. Budget: narrowing
//! (2^-11 / 2^-8) + compute (≤ 2^-20 after the amax normalisation)
//! with 2× headroom for the error of the *reference* rounding and the
//! outlier-heavy payloads:
//!
//! * f16:  2 · 2^-11 ≈ 9.8e-4
//! * bf16: 2 · 2^-8  ≈ 7.8e-3
//!
//! A genuine algorithmic regression (a dropped round, a wrong residual
//! factor) produces errors at the 1e-1..1e0 scale — orders of magnitude
//! above these gates.

use hadacore::exec::ExecEngine;
use hadacore::hadamard::matrices::matvec_hadamard_n;
use hadacore::hadamard::{FwhtOptions, KernelKind};
use hadacore::util::f16::{DType, Element, BF16, F16};
use hadacore::util::rng::Rng;

/// The supported-size family under test: powers of two across the
/// paper's range plus every non-power-of-two base (12·64, 20·256,
/// 28·512 — the Llama-3 8B FFN dim).
const FAMILY: [usize; 7] = [256, 1024, 4096, 16384, 768, 5120, 14336];

/// Max |got − want| / max|want| of one row, in f64.
fn rel_to_amax(got: &[f32], want: &[f32]) -> f64 {
    let amax = want.iter().fold(0.0f64, |m, v| m.max((*v as f64).abs()));
    let maxdiff = got
        .iter()
        .zip(want)
        .fold(0.0f64, |m, (g, w)| m.max((*g as f64 - *w as f64).abs()));
    maxdiff / amax.max(1e-300)
}

/// Threshold for a dtype (derived in the module header).
fn threshold(dtype: DType) -> f64 {
    match dtype {
        DType::F16 => 2.0 * (2f64).powi(-11),
        DType::BF16 => 2.0 * (2f64).powi(-8),
        DType::F32 => unreachable!("16-bit test"),
    }
}

fn check_dtype<E: Element + hadacore::exec::ExecElement>(dtype: DType) {
    let mut rng = Rng::new(0xACC ^ dtype.size_bytes() as u64);
    let engine = ExecEngine::default();
    let mut worst: (f64, usize) = (0.0, 0);
    for n in FAMILY {
        // outlier-bearing payload (the activation regime the rotation
        // targets), pre-narrowed so the 16-bit input is exact
        let raw: Vec<f32> = (0..n).map(|_| rng.outlier_normal(0.05, 30.0)).collect();
        let narrowed: Vec<E> = raw.iter().map(|&v| E::from_f32(v)).collect();
        let widened: Vec<f32> = narrowed.iter().map(|v| v.to_f32()).collect();

        // dense f32 reference on the widened input, orthonormal scale
        let mut want = vec![0.0f32; n];
        matvec_hadamard_n(&widened, n, &mut want);
        let scale = 1.0 / (n as f32).sqrt();
        for v in want.iter_mut() {
            *v *= scale;
        }

        // the 16-bit serving path (engine, autotuned) end to end
        let mut got16 = narrowed;
        engine.run(KernelKind::HadaCore, &mut got16, n, &FwhtOptions::normalized(n));
        let got: Vec<f32> = got16.iter().map(|v| v.to_f32()).collect();

        let err = rel_to_amax(&got, &want);
        let gate = threshold(dtype);
        assert!(
            err <= gate,
            "{} n={n}: max rel-to-amax error {err:.3e} exceeds the derived \
             bound {gate:.3e}",
            dtype.name()
        );
        if err > worst.0 {
            worst = (err, n);
        }
    }
    // the bound must also not be vacuous: a real 16-bit rounding error
    // should show up within two decades of the gate at some size
    assert!(
        worst.0 > threshold(dtype) / 100.0,
        "{}: worst error {:.3e} implausibly small — is the 16-bit path \
         actually narrowing? (worst at n={})",
        dtype.name(),
        worst.0,
        worst.1
    );
}

#[test]
fn f16_transform_error_is_bounded_by_the_derived_threshold() {
    check_dtype::<F16>(DType::F16);
}

#[test]
fn bf16_transform_error_is_bounded_by_the_derived_threshold() {
    check_dtype::<BF16>(DType::BF16);
}

#[test]
fn f16_error_grows_with_format_coarseness() {
    // sanity on the derivation's ordering: at the same payload, bf16's
    // coarser fraction must produce a larger (or equal) error than f16
    let n = 4096;
    let mut rng = Rng::new(0xACC2);
    let raw: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let engine = ExecEngine::single_threaded();

    let mut err = [0.0f64; 2];
    for (slot, coarse) in [(0usize, false), (1, true)] {
        let (widened, got): (Vec<f32>, Vec<f32>) = if coarse {
            let x: Vec<BF16> = raw.iter().map(|&v| BF16::from_f32(v)).collect();
            let w = x.iter().map(|v| v.to_f32()).collect();
            let mut d = x;
            engine.run(KernelKind::HadaCore, &mut d, n, &FwhtOptions::normalized(n));
            (w, d.iter().map(|v| v.to_f32()).collect())
        } else {
            let x: Vec<F16> = raw.iter().map(|&v| F16::from_f32(v)).collect();
            let w = x.iter().map(|v| v.to_f32()).collect();
            let mut d = x;
            engine.run(KernelKind::HadaCore, &mut d, n, &FwhtOptions::normalized(n));
            (w, d.iter().map(|v| v.to_f32()).collect())
        };
        let mut want = vec![0.0f32; n];
        matvec_hadamard_n(&widened, n, &mut want);
        let scale = 1.0 / (n as f32).sqrt();
        for v in want.iter_mut() {
            *v *= scale;
        }
        err[slot] = rel_to_amax(&got, &want);
    }
    assert!(
        err[1] >= err[0],
        "bf16 error {:.3e} should dominate f16 error {:.3e}",
        err[1],
        err[0]
    );
}
