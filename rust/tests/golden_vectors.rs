//! Golden-vector regression suite: transform outputs locked to
//! checked-in digests so *silent numeric drift fails loudly*.
//!
//! Coverage: all kernels (scalar / dao / hadacore, plus the planned +
//! engine hadacore paths) × sizes {256, 1024, 768 = 12·64,
//! 5120 = 20·256, 14336 = 28·512} × dtypes {f32, f16, bf16}, under the
//! serving-default orthonormal scale — each case both plain and with
//! the seeded sign-flip rotation prologue (`prologue_seed` entries),
//! so the randomized-rotation path is digest-locked too.
//!
//! ## Why the goldens are platform-exact
//!
//! Inputs are derived from the deterministic [`Rng`] (`util/rng.rs`)
//! **raw u64 stream** mapped to dyadic rationals (`k / 2^16`,
//! `|v| < 128`) — no transcendental functions anywhere in the input
//! path, so the inputs are bit-identical on every platform and
//! toolchain. The kernels then use only IEEE add/sub/mul (+ one
//! correctly-rounded sqrt for the scale) in a deterministic order, so
//! outputs are bit-identical too. Goldens therefore store IEEE **bit
//! patterns** (a 16-element prefix verbatim plus an FNV-1a-64 digest of
//! the full output), never decimal floats.
//!
//! ## Regenerating (`--regen` path)
//!
//! After an *intentional* numeric change, rewrite the golden files from
//! the current implementation and commit the diff:
//!
//! ```text
//! cargo test --test golden_vectors -- --ignored regen_golden_vectors --nocapture
//! ```
//!
//! (the `regen_golden_vectors` target below; it overwrites
//! `tests/golden/*.json` in the source tree via `CARGO_MANIFEST_DIR`).
//! Review the diff like any other behavioural change — an unexplained
//! digest flip is exactly what this suite exists to catch.

use hadacore::exec::ExecEngine;
use hadacore::hadamard::{
    apply_signs, fwht_f32, fwht_generic, sign_vector, FwhtOptions, KernelKind, Prologue,
};
use hadacore::quant::Epilogue;
use hadacore::util::f16::{DType, Element, BF16, F16};
use hadacore::util::json::Json;
use hadacore::util::rng::Rng;

/// Schema tag of the golden files.
const GOLDEN_SCHEMA: &str = "hadacore-golden-v1";

/// Locked sizes: two powers of two + one of each non-power-of-two base
/// (12·64, 20·256, 28·512 — the Llama-3 8B FFN dim).
const GOLDEN_SIZES: [usize; 5] = [256, 1024, 768, 5120, 14336];

/// Base seed; each size derives its own stream as `SEED ^ n`.
const GOLDEN_SEED: u64 = 0x601D;

/// Fixed rotation seed of the sign-flip-prologue golden entries (must
/// match `python/goldens.py::ROTATED_SEED`).
const ROTATED_SEED: u64 = 0x5EED_0006;

/// Output-prefix elements stored verbatim (as bit patterns).
const PREFIX_LEN: usize = 16;

fn golden_rows(n: usize) -> usize {
    if n <= 1024 {
        3
    } else {
        2
    }
}

/// Dyadic input stream: `(u64 >> 40) - 2^23` over `2^16` — exactly
/// representable in f32 (24-bit numerators), no transcendentals.
fn golden_input(n: usize) -> Vec<f32> {
    let mut rng = Rng::new(GOLDEN_SEED ^ n as u64);
    let rows = golden_rows(n);
    (0..rows * n)
        .map(|_| ((rng.next_u64() >> 40) as i64 - (1 << 23)) as f32 / 65536.0)
        .collect()
}

/// FNV-1a 64 over little-endian bytes.
struct Fnv64(u64);

impl Fnv64 {
    fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }
    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }
}

/// The transformed output of one (kernel, n, dtype, prologue) case, as
/// bit patterns (u32 per element for f32, u16 widened to u32 for
/// 16-bit). Rotated cases apply the sign flip as an **explicit
/// premultiply** (`apply_signs` on the widened values) before the plain
/// transform — the unfused reference the engine's fused prologue is
/// digest-locked against.
fn transform_bits(kind: KernelKind, n: usize, dtype: DType, prologue: Option<u64>) -> Vec<u32> {
    let input = golden_input(n);
    let opts = FwhtOptions::normalized(n);
    let signs = prologue.map(|seed| sign_vector(seed, n));
    match dtype {
        DType::F32 => {
            let mut data = input;
            if let Some(s) = &signs {
                apply_signs(&mut data, s);
            }
            fwht_f32(kind, &mut data, n, &opts);
            data.iter().map(|v| v.to_bits()).collect()
        }
        DType::F16 => {
            // flip the *widened* values then narrow back: multiplying
            // by ±1.0 is exact, so this equals flipping the narrow bits
            let mut wide: Vec<f32> = input.iter().map(|&v| F16::from_f32(v).to_f32()).collect();
            if let Some(s) = &signs {
                apply_signs(&mut wide, s);
            }
            let mut data: Vec<F16> = wide.iter().map(|&v| F16::from_f32(v)).collect();
            fwht_generic(kind, &mut data, n, &opts);
            data.iter().map(|v| v.0 as u32).collect()
        }
        DType::BF16 => {
            let mut wide: Vec<f32> =
                input.iter().map(|&v| BF16::from_f32(v).to_f32()).collect();
            if let Some(s) = &signs {
                apply_signs(&mut wide, s);
            }
            let mut data: Vec<BF16> = wide.iter().map(|&v| BF16::from_f32(v)).collect();
            fwht_generic(kind, &mut data, n, &opts);
            data.iter().map(|v| v.0 as u32).collect()
        }
    }
}

/// Same case through the batched engine (default tuned policy) — must
/// produce the identical bit stream. Rotated cases go through the
/// **fused** [`Prologue::SignFlip`] path, so every golden rotated entry
/// also re-proves fused == premultiplied at the digest level.
fn engine_bits(kind: KernelKind, n: usize, dtype: DType, prologue: Option<u64>) -> Vec<u32> {
    let engine = ExecEngine::default();
    let input = golden_input(n);
    let opts = FwhtOptions::normalized(n);
    let pro = match prologue {
        Some(seed) => Prologue::SignFlip { seed },
        None => Prologue::None,
    };
    match dtype {
        DType::F32 => {
            let mut data = input;
            engine.run_with_stages(kind, &mut data, n, &opts, pro, Epilogue::None);
            data.iter().map(|v| v.to_bits()).collect()
        }
        DType::F16 => {
            let mut data: Vec<F16> = input.iter().map(|&v| F16::from_f32(v)).collect();
            engine.run_with_stages(kind, &mut data, n, &opts, pro, Epilogue::None);
            data.iter().map(|v| v.0 as u32).collect()
        }
        DType::BF16 => {
            let mut data: Vec<BF16> =
                input.iter().map(|&v| BF16::from_f32(v)).collect();
            engine.run_with_stages(kind, &mut data, n, &opts, pro, Epilogue::None);
            data.iter().map(|v| v.0 as u32).collect()
        }
    }
}

fn digest(bits: &[u32], dtype: DType) -> String {
    let mut h = Fnv64::new();
    for &b in bits {
        match dtype {
            DType::F32 => h.update(&b.to_le_bytes()),
            DType::F16 | DType::BF16 => h.update(&(b as u16).to_le_bytes()),
        }
    }
    format!("{:#018x}", h.0)
}

fn golden_path(dtype: DType) -> String {
    format!(
        "{}/tests/golden/{}.json",
        env!("CARGO_MANIFEST_DIR"),
        dtype.name()
    )
}

fn entry_json(kind: KernelKind, n: usize, dtype: DType, prologue: Option<u64>) -> Json {
    let bits = transform_bits(kind, n, dtype, prologue);
    let mut fields = vec![
        ("kernel", Json::str(kind.name())),
        ("n", Json::num(n as f64)),
        ("rows", Json::num(golden_rows(n) as f64)),
        ("seed", Json::num((GOLDEN_SEED ^ n as u64) as f64)),
        (
            "prefix_bits",
            Json::Arr(
                bits.iter().take(PREFIX_LEN).map(|&b| Json::num(b as f64)).collect(),
            ),
        ),
        ("fnv64", Json::str(digest(&bits, dtype))),
    ];
    if let Some(seed) = prologue {
        fields.push(("prologue_seed", Json::num(seed as f64)));
    }
    Json::obj(fields)
}

fn check_dtype(dtype: DType) {
    let path = golden_path(dtype);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden file {path}: {e} (run the regen target — see the file header)"));
    let doc = Json::parse(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
    assert_eq!(
        doc.get("schema").and_then(Json::as_str),
        Some(GOLDEN_SCHEMA),
        "{path}: schema tag"
    );
    let entries = doc.get("entries").and_then(Json::as_arr).expect("entries");
    // every (kernel, size) case appears twice: plain + rotated
    assert_eq!(
        entries.len(),
        2 * GOLDEN_SIZES.len() * KernelKind::all().len(),
        "{path}: entry count"
    );
    let mut rotated_seen = 0usize;
    for e in entries {
        let kernel = e.get("kernel").and_then(Json::as_str).expect("kernel");
        let kind = KernelKind::parse(kernel).expect("known kernel");
        let n = e.get("n").and_then(Json::as_usize).expect("n");
        let rows = e.get("rows").and_then(Json::as_usize).expect("rows");
        assert_eq!(rows, golden_rows(n), "locked row count changed");
        let prologue = e
            .get("prologue_seed")
            .map(|v| v.as_usize().expect("prologue_seed") as u64);
        if let Some(seed) = prologue {
            assert_eq!(seed, ROTATED_SEED, "locked rotation seed changed");
            rotated_seen += 1;
        }
        let want_prefix: Vec<u32> = e
            .get("prefix_bits")
            .and_then(Json::as_arr)
            .expect("prefix_bits")
            .iter()
            .map(|v| v.as_usize().expect("bit pattern") as u32)
            .collect();
        let want_fnv = e.get("fnv64").and_then(Json::as_str).expect("fnv64");

        let bits = transform_bits(kind, n, dtype, prologue);
        let got_prefix = &bits[..PREFIX_LEN.min(bits.len())];
        assert_eq!(
            got_prefix,
            &want_prefix[..],
            "golden drift: {kernel} n={n} dtype={} prologue={prologue:?} (prefix)",
            dtype.name()
        );
        assert_eq!(
            digest(&bits, dtype),
            want_fnv,
            "golden drift: {kernel} n={n} dtype={} prologue={prologue:?} (digest) — if \
             this change is intentional, regenerate (file header)",
            dtype.name()
        );

        // the batched engine must serve the same bits it locked; for
        // rotated entries this runs the fused prologue against the
        // premultiplied reference digest
        assert_eq!(
            engine_bits(kind, n, dtype, prologue),
            bits,
            "engine diverged from the golden path: {kernel} n={n} dtype={} prologue={prologue:?}",
            dtype.name()
        );
    }
    assert_eq!(
        rotated_seen,
        GOLDEN_SIZES.len() * KernelKind::all().len(),
        "{path}: rotated entry count"
    );
}

#[test]
fn golden_vectors_f32() {
    check_dtype(DType::F32);
}

#[test]
fn golden_vectors_f16() {
    check_dtype(DType::F16);
}

#[test]
fn golden_vectors_bf16() {
    check_dtype(DType::BF16);
}

/// Every reachable SIMD backend must reproduce **all 90 golden
/// digests** (30 entries × 3 dtypes) without regeneration: the vector
/// butterflies are bit-identical to the scalar bodies by construction
/// (`docs/KERNEL_MATH.md` §8), so the goldens pinned before the SIMD
/// dispatch existed stay valid under every table. Forcing is
/// process-global but benign for the sibling tests in this binary —
/// they assert the very property (backend-independence of the bits)
/// this test sweeps.
#[test]
fn golden_vectors_hold_under_every_forced_simd_backend() {
    use hadacore::hadamard::simd::{self, Backend};
    for backend in Backend::all().into_iter().filter(|&b| simd::reachable(b)) {
        let prev = simd::force(backend).expect("backend reachable");
        let before = simd::dispatch_count(backend);
        for dtype in [DType::F32, DType::F16, DType::BF16] {
            check_dtype(dtype);
        }
        let after = simd::dispatch_count(backend);
        simd::force(prev).expect("restore backend");
        assert!(
            after > before,
            "non-vacuity: goldens never dispatched through {}",
            backend.name()
        );
    }
}

#[test]
fn golden_inputs_are_dyadic_and_deterministic() {
    // the platform-exactness argument rests on these two properties
    for n in GOLDEN_SIZES {
        let a = golden_input(n);
        let b = golden_input(n);
        assert_eq!(a, b);
        for v in &a {
            assert!(v.abs() < 128.0);
            // representable as k / 2^16 with |k| < 2^23: scaling back up
            // is exact and integral
            let k = (v * 65536.0) as i64;
            assert_eq!(*v, k as f32 / 65536.0);
        }
    }
}

/// Rewrite `tests/golden/*.json` from the current implementation — the
/// documented `--regen` path (see the file header). `#[ignore]`d so a
/// plain `cargo test` never mutates the source tree.
#[test]
#[ignore = "regenerates the checked-in goldens; run explicitly after an intentional numeric change"]
fn regen_golden_vectors() {
    for dtype in [DType::F32, DType::F16, DType::BF16] {
        let mut entries = Vec::new();
        for &n in &GOLDEN_SIZES {
            for kind in KernelKind::all() {
                entries.push(entry_json(kind, n, dtype, None));
            }
        }
        for &n in &GOLDEN_SIZES {
            for kind in KernelKind::all() {
                entries.push(entry_json(kind, n, dtype, Some(ROTATED_SEED)));
            }
        }
        let doc = Json::obj(vec![
            ("schema", Json::str(GOLDEN_SCHEMA)),
            ("dtype", Json::str(dtype.name())),
            ("prefix_len", Json::num(PREFIX_LEN as f64)),
            ("entries", Json::Arr(entries)),
        ]);
        let path = golden_path(dtype);
        std::fs::write(&path, doc.to_pretty()).expect("write golden file");
        println!("regenerated {path}");
    }
}
