//! Integration: AOT artifacts (L1/L2, lowered by python) executed through
//! the PJRT runtime must agree with the native Rust kernels (L3 substrate).
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! notice) when the artifact directory is absent so `cargo test` stays
//! green on a fresh checkout.

use hadacore::hadamard::{fwht_f32, FwhtOptions, KernelKind};
use hadacore::runtime::{literal_f32, literal_i32, literal_to_f32, Runtime, Tensor};
use hadacore::util::prop::{assert_close, rel_l2};
use hadacore::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(dir).expect("runtime open"))
}

#[test]
fn fwht_artifact_matches_native_kernel() {
    let Some(rt) = runtime() else { return };
    for (kernel, n) in [("hadacore", 256usize), ("hadacore", 1024), ("butterfly", 1024)] {
        let entry = rt.find_fwht(kernel, n).expect("bucket exists").clone();
        let rows = entry.rows.unwrap();
        let art = rt.load(&entry.name).expect("load artifact");

        let mut rng = Rng::new(42 + n as u64);
        let x = rng.normal_vec(rows * n);
        let input = Tensor::new(vec![rows, n], x.clone()).unwrap();
        let out = art.execute_f32(&input).expect("execute");

        let mut want = x;
        fwht_f32(KernelKind::HadaCore, &mut want, n, &FwhtOptions::normalized(n));
        assert_close(&out.data, &want, 2e-3, 2e-3);
    }
}

#[test]
fn fwht_artifact_involution() {
    let Some(rt) = runtime() else { return };
    let entry = rt.find_fwht("hadacore", 512).unwrap().clone();
    let rows = entry.rows.unwrap();
    let art = rt.load(&entry.name).unwrap();
    let mut rng = Rng::new(7);
    let x = rng.normal_vec(rows * 512);
    let t = Tensor::new(vec![rows, 512], x.clone()).unwrap();
    let once = art.execute_f32(&t).unwrap();
    let twice = art.execute_f32(&once).unwrap();
    assert_close(&twice.data, &x, 1e-3, 1e-3);
}

#[test]
fn runtime_failure_modes_are_clean_errors() {
    // missing directory
    assert!(Runtime::open("/nonexistent/artifacts-dir").is_err());

    // malformed manifest
    let dir = std::env::temp_dir().join(format!("hc_bad_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Runtime::open(&dir).is_err());

    // valid manifest referencing a missing / corrupt artifact file
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [
              {"name": "ghost", "op": "fwht", "file": "ghost.hlo.txt",
               "inputs": [], "outputs": []},
              {"name": "corrupt", "op": "fwht", "file": "corrupt.hlo.txt",
               "inputs": [], "outputs": []}],
            "weights": [], "model": {}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("corrupt.hlo.txt"), "HloModule nope ENTRY {").unwrap();
    let rt = Runtime::open(&dir).unwrap();
    assert!(rt.load("ghost").is_err(), "missing file must error");
    assert!(rt.load("corrupt").is_err(), "corrupt HLO must error");
    assert!(rt.load("unlisted").is_err(), "unknown name must error");
    // weights.bin absent
    assert!(rt.weights().is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn all_artifacts_compile() {
    let Some(rt) = runtime() else { return };
    let count = rt.load_all().expect("load_all");
    assert!(count >= 19, "expected >= 19 artifacts, got {count}");
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn attention_variants_rotation_behaviour() {
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest().model.clone();
    let (b, t, d) = (meta.attn_batch, meta.seq_len, meta.dim);

    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..b * t * d).map(|_| rng.normal_f32()).collect();
    // channel-structured outliers: a few projection columns systematically
    // large (how outlier channels arise in real LLMs — the regime QuaRot
    // rotations target). i.i.d. outliers would already be "rotated".
    let w: Vec<Vec<f32>> = (0..4)
        .map(|wi| {
            let mut m: Vec<f32> = (0..d * d)
                .map(|_| rng.normal_f32() / (d as f32).sqrt())
                .collect();
            if wi < 3 {
                for c in [3usize, 17, 40] {
                    for r in 0..d {
                        m[r * d + c] *= 30.0;
                    }
                }
            }
            m
        })
        .collect();

    let run = |name: &str| -> Vec<f32> {
        let art = rt.load(name).expect(name);
        let mut lits = vec![literal_f32(&x, &[b, t, d]).unwrap()];
        for wi in &w {
            lits.push(literal_f32(wi, &[d, d]).unwrap());
        }
        let outs = art.execute(&lits).expect(name);
        literal_to_f32(&outs[0]).unwrap()
    };

    let clean = run("attn_fp16");
    let fp8 = run("attn_fp8_norot");
    let fp8_hc = run("attn_fp8_rot_hadacore");
    let fp8_bf = run("attn_fp8_rot_butterfly");
    let i8_no = run("attn_int8_norot");
    let i8_hc = run("attn_int8_rot_hadacore");
    let i8_bf = run("attn_int8_rot_butterfly");

    let e_fp8 = rel_l2(&fp8, &clean);
    let e_fp8_hc = rel_l2(&fp8_hc, &clean);
    let e_fp8_bf = rel_l2(&fp8_bf, &clean);
    let e_i8 = rel_l2(&i8_no, &clean);
    let e_i8_hc = rel_l2(&i8_hc, &clean);
    let e_i8_bf = rel_l2(&i8_bf, &clean);
    eprintln!(
        "attention error vs clean:\n  fp8:  norot={e_fp8:.5} hadacore={e_fp8_hc:.5} butterfly={e_fp8_bf:.5}\n  int8: norot={e_i8:.5} hadacore={e_i8_hc:.5} butterfly={e_i8_bf:.5}"
    );

    // INT8 (uniform quantiser): rotation must reduce error — the QuaRot
    // mechanism the paper's §1 motivates.
    assert!(
        e_i8_hc < e_i8 * 0.8,
        "hadacore rotation should cut int8 error: {e_i8_hc} vs {e_i8}"
    );
    assert!(e_i8_bf < e_i8 * 0.8, "butterfly rotation should cut int8 error");

    // The paper's §4.2 parity claim: HadaCore's numerics match the exact
    // (butterfly/Dao) kernel — for both quantisers.
    let kernel_gap_fp8 = rel_l2(&fp8_hc, &fp8_bf);
    let kernel_gap_i8 = rel_l2(&i8_hc, &i8_bf);
    assert!(
        kernel_gap_fp8 < 5e-3,
        "hadacore vs butterfly rotation paths differ (fp8): {kernel_gap_fp8}"
    );
    assert!(
        kernel_gap_i8 < 5e-3,
        "hadacore vs butterfly rotation paths differ (int8): {kernel_gap_i8}"
    );

    // FP8 (float format, per-tensor scale) is documented rotation-neutral:
    // just require rotation not to blow the error up pathologically.
    assert!(e_fp8_hc < e_fp8 * 3.0, "fp8 rotation sanity: {e_fp8_hc} vs {e_fp8}");
}

#[test]
fn lm_forward_executes_with_trained_weights() {
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest().model.clone();
    let weights = rt.weights().expect("weights");
    assert!(weights.param_count() > 100_000);

    let art = rt.load("lm_fp16").expect("lm_fp16");
    let tokens: Vec<i32> =
        (0..meta.lm_batch * meta.seq_len).map(|i| (i % meta.vocab) as i32).collect();
    let mut lits = vec![literal_i32(&tokens, &[meta.lm_batch, meta.seq_len]).unwrap()];
    lits.extend(weights.to_literals().unwrap());
    let outs = art.execute(&lits).expect("lm execute");
    let logits = literal_to_f32(&outs[0]).unwrap();
    assert_eq!(logits.len(), meta.lm_batch * meta.seq_len * meta.vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
    // logits must be non-degenerate (trained model, varied inputs)
    let spread = logits.iter().cloned().fold(f32::MIN, f32::max)
        - logits.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 1.0, "logit spread {spread}");
}
