//! Forced-dispatch SIMD parity matrix (ISSUE 8 acceptance gate).
//!
//! For **every backend reachable on this host**, force it through
//! [`hadacore::hadamard::simd::force`] and assert the transform output
//! is **bit-for-bit identical** to the same transform executed with the
//! dispatch table forced to [`Backend::Scalar`] — the portable
//! reference bodies in `hadamard/simd/scalar.rs` that every vector
//! kernel must reproduce exactly (no FMA, no reassociation, no
//! zero-skipping; see `docs/KERNEL_MATH.md` §8). The grid:
//!
//! * sizes {256, 1024, 768 = 12·64, 5120 = 20·256, 14336 = 28·512,
//!   32768} — pow2 plus every non-pow2 base family;
//! * every admissible fusion depth of the planned HadaCore path (plus
//!   one past the round count, which clamps);
//! * batch lane counts (rows) 1 / 3 / 8;
//! * engine chunk boundaries (a sharded multi-chunk engine with a tiny
//!   chunk floor vs the single-threaded inline path);
//! * both dispatched kernel families (HadaCore and the Dao baseline)
//!   and the fused sign-flip prologue rail.
//!
//! **Non-vacuity**: each forced leg also asserts the backend's
//! process-wide dispatch counter advanced — a backend that silently
//! fell back to scalar would pass every bit-equality check, so the
//! counters are the proof the vector path actually ran (surfaced
//! through `ExecStatsSnapshot::{simd_backend, simd_dispatches}` too).
//!
//! The dispatch state is process-global, so every test here serialises
//! on one lock and restores the previously active backend before
//! releasing it. Interleaving with *other* test binaries is a
//! non-issue: they are separate processes.

use std::sync::{Mutex, MutexGuard};

use hadacore::exec::{ExecConfig, ExecEngine, TunePolicy};
use hadacore::hadamard::hadacore::{
    fwht_hadacore_f32_planned_depth, HadaCoreConfig, HadaCorePlan,
};
use hadacore::hadamard::simd::{self, Backend};
use hadacore::hadamard::{fwht_f32, FwhtOptions, KernelKind, Prologue};
use hadacore::quant::Epilogue;
use hadacore::util::rng::Rng;

/// The full size grid: {256, 1024, 12·64, 20·256, 28·512, 32768}.
const SIZES: [usize; 6] = [256, 1024, 768, 5120, 14336, 32768];

/// Batch lane counts (rows per batch).
const ROWS: [usize; 3] = [1, 3, 8];

static DISPATCH_LOCK: Mutex<()> = Mutex::new(());

fn dispatch_guard() -> MutexGuard<'static, ()> {
    DISPATCH_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn reachable_backends() -> Vec<Backend> {
    Backend::all().into_iter().filter(|&b| simd::reachable(b)).collect()
}

/// Dyadic deterministic inputs (`k / 2^16`, |v| < 128): bit-exact on
/// every platform, same construction as the golden vectors.
fn dyadic_input(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..len)
        .map(|_| ((rng.next_u64() >> 40) as i64 - (1 << 23)) as f32 / 65536.0)
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Force `backend`, run `body`, restore the previous backend, and
/// return `body`'s result. The non-vacuity counter check lives in the
/// call sites that need it (forcing Scalar for the oracle leg must not
/// require *vector* dispatches, for instance).
fn under<R>(backend: Backend, body: impl FnOnce() -> R) -> R {
    let prev = simd::force(backend).expect("backend reachable");
    let out = body();
    simd::force(prev).expect("restore backend");
    out
}

/// [`under`] plus the non-vacuity assertion: the forced backend's
/// dispatch counter must advance while `body` runs.
fn under_counted<R>(backend: Backend, body: impl FnOnce() -> R) -> R {
    let before = simd::dispatch_count(backend);
    let out = under(backend, body);
    let after = simd::dispatch_count(backend);
    assert!(
        after > before,
        "non-vacuity: forced backend {} served no dispatches",
        backend.name()
    );
    out
}

/// Direct planned-path grid: every (size × fusion depth × rows ×
/// kernel) cell, per reachable backend, bit-identical to the same cell
/// under the forced scalar table.
#[test]
fn forced_backends_match_scalar_across_sizes_depths_and_rows() {
    let _g = dispatch_guard();
    let backends = reachable_backends();
    for &n in &SIZES {
        let plan = HadaCorePlan::new(n, &HadaCoreConfig::default());
        let opts = FwhtOptions::normalized(n);
        for &rows in &ROWS {
            let input = dyadic_input(0x51_3D ^ n as u64, rows * n);
            // one transform closure per cell so the oracle and every
            // backend run byte-for-byte the same code path
            for depth in 1..=plan.max_fusion_depth() + 1 {
                let cell = || {
                    let mut got = input.clone();
                    for row in got.chunks_exact_mut(n) {
                        fwht_hadacore_f32_planned_depth(row, &plan, &opts, depth);
                    }
                    bits(&got)
                };
                let want = under(Backend::Scalar, cell);
                for &backend in &backends {
                    let got = under_counted(backend, cell);
                    assert_eq!(
                        got,
                        want,
                        "{} diverged: n={n} rows={rows} depth={depth}",
                        backend.name()
                    );
                }
            }
            // the Dao baseline shares the dispatched strided/base entry
            // points — cover that family too
            let dao_cell = || {
                let mut got = input.clone();
                fwht_f32(KernelKind::Dao, &mut got, n, &opts);
                bits(&got)
            };
            let want = under(Backend::Scalar, dao_cell);
            for &backend in &backends {
                let got = under_counted(backend, dao_cell);
                assert_eq!(
                    got,
                    want,
                    "{} dao diverged: n={n} rows={rows}",
                    backend.name()
                );
            }
        }
    }
}

/// Engine grid: chunk boundaries (sharded pool with a tiny chunk floor
/// vs inline single-thread), every forced fusion depth, rotated
/// prologue included — each cell bit-identical to the same engine cell
/// under the forced scalar table.
#[test]
fn forced_backends_match_scalar_through_the_engine_and_chunking() {
    let _g = dispatch_guard();
    let backends = reachable_backends();
    let seed = 0x5EED_0008u64;
    for &n in &[1024usize, 5120, 14336] {
        let opts = FwhtOptions::normalized(n);
        let rows = 9; // odd: exercises ragged chunk tails
        let input = dyadic_input(0xE7_91 ^ n as u64, rows * n);
        let plan = HadaCorePlan::new(n, &HadaCoreConfig::default());
        for depth in 1..=plan.max_fusion_depth() {
            for threads in [1usize, 4] {
                let make_engine = || {
                    ExecEngine::new(ExecConfig {
                        threads,
                        chunks_per_thread: 4,
                        // tiny floor => many chunks => boundaries
                        min_chunk_elems: 1,
                        tune: TunePolicy::FixedDepth(depth),
                    })
                };
                let plain = || {
                    let engine = make_engine();
                    let mut got = input.clone();
                    engine.run_f32(KernelKind::HadaCore, &mut got, n, &opts);
                    bits(&got)
                };
                let want = under(Backend::Scalar, plain);
                for &backend in &backends {
                    let got = under_counted(backend, plain);
                    assert_eq!(
                        got,
                        want,
                        "{} engine diverged: n={n} depth={depth} threads={threads}",
                        backend.name()
                    );
                }
                // rotated: the fused sign-flip prologue rides the same
                // dispatched chunk traversal
                let rotated = || {
                    let engine = make_engine();
                    let mut got = input.clone();
                    let _ = engine.run_f32_with_stages(
                        KernelKind::HadaCore,
                        &mut got,
                        n,
                        &opts,
                        Prologue::SignFlip { seed },
                        Epilogue::None,
                    );
                    bits(&got)
                };
                let want_rot = under(Backend::Scalar, rotated);
                for &backend in &backends {
                    let got = under_counted(backend, rotated);
                    assert_eq!(
                        got,
                        want_rot,
                        "{} rotated engine diverged: n={n} depth={depth} \
                         threads={threads}",
                        backend.name()
                    );
                }
            }
        }
    }
}

/// The engine's stats snapshot reports the forced backend by name and a
/// dispatch count that advances with traffic — the observable the
/// loadgen/bench records carry.
#[test]
fn stats_snapshot_reports_the_forced_backend_and_counts() {
    let _g = dispatch_guard();
    for backend in reachable_backends() {
        under(backend, || {
            let engine = ExecEngine::single_threaded();
            let s0 = engine.stats();
            assert_eq!(s0.simd_backend, backend.name());
            let n = 1024;
            let opts = FwhtOptions::normalized(n);
            let mut data = dyadic_input(7, 4 * n);
            engine.run_f32(KernelKind::HadaCore, &mut data, n, &opts);
            let s1 = engine.stats();
            assert!(
                s1.simd_dispatches > s0.simd_dispatches,
                "{}: dispatch counter must advance",
                backend.name()
            );
        });
    }
}

/// The env choice is frozen at first use: mutating `HADACORE_SIMD`
/// after the first dispatch must not move the active backend (the
/// same freeze contract as `HADACORE_TUNE`).
#[test]
fn env_choice_is_frozen_after_first_dispatch() {
    let _g = dispatch_guard();
    let original = std::env::var("HADACORE_SIMD").ok();
    let active = simd::active(); // freezes the env choice
    std::env::set_var(
        "HADACORE_SIMD",
        if active == Backend::Scalar { "auto" } else { "off" },
    );
    assert_eq!(
        simd::active(),
        active,
        "HADACORE_SIMD must be frozen at first use"
    );
    match original {
        Some(v) => std::env::set_var("HADACORE_SIMD", v),
        None => std::env::remove_var("HADACORE_SIMD"),
    }
}

/// Forcing never changes *results*, only provenance: a full transform
/// under each backend in sequence produces one identical bit stream.
/// (This is the property that makes global-dispatch races benign for
/// every other test in the repo.)
#[test]
fn backend_switching_mid_process_is_observably_pure() {
    let _g = dispatch_guard();
    let n = 768;
    let opts = FwhtOptions::normalized(n);
    let input = dyadic_input(0xABCD, 3 * n);
    let mut outputs: Vec<Vec<u32>> = Vec::new();
    for backend in reachable_backends() {
        let got = under(backend, || {
            let mut got = input.clone();
            fwht_f32(KernelKind::HadaCore, &mut got, n, &opts);
            bits(&got)
        });
        outputs.push(got);
    }
    for w in outputs.windows(2) {
        assert_eq!(w[0], w[1], "backends disagree");
    }
}
