//! Integration: non-power-of-two `B * 2^k` transform sizes, end to end.
//!
//! The acceptance bar (ISSUE 3): `fwht` at n = 14336 (28·512, the
//! Llama-3 8B FFN dim) must match a dense `x @ H_n` reference
//! **bit-for-bit in f32** through both the direct kernel and the batched
//! exec engine.
//!
//! Bit-for-bit against an O(n²) dense reference is achievable because
//! the payloads here are small *integers*: every product is ±x, every
//! partial sum is an integer, and the largest possible magnitude
//! (`n * max|x| = 14336 * 4 < 2^24`) is exactly representable in f32 —
//! so every association of the sum (dense f64 accumulate, factored
//! kernel stages, sharded chunks) computes the same exact integer and
//! rounds to identical bits. Random real-valued payloads are covered by
//! the tolerance-based tests in `exec_parity.rs` and the property suite.

use hadacore::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RouterConfig, TransformRequest,
};
use hadacore::exec::{ExecConfig, ExecEngine};
use hadacore::hadamard::matrices::matvec_hadamard_n;
use hadacore::hadamard::{fwht_f32, FwhtOptions, KernelKind};
use hadacore::quant::{
    fp8_quantize_slice, int_quantize_grouped, Epilogue, Fp8Format, IntBits,
    QuantScales,
};
use hadacore::util::prop::assert_close;
use hadacore::util::rng::Rng;
use std::time::Duration;

/// Integer-valued payload in [-4, 4] — see the module doc for why this
/// makes every path bit-exact.
fn integer_payload(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.below(9) as f32 - 4.0).collect()
}

/// The satellite size grid: every base x a serving-scale 2^k.
const NPOT_SHAPES: [(usize, usize); 4] = [(768, 33), (5120, 9), (14336, 3), (40960, 2)];

#[test]
fn acceptance_14336_matches_dense_reference_bit_for_bit() {
    let n = 14336; // 28 * 512
    let rows = 2;
    let mut rng = Rng::new(0xACCE);
    let x = integer_payload(&mut rng, rows * n);

    // dense reference: y = x @ H_n, entries computed from the Kronecker
    // factorisation, f64 accumulate with one final rounding
    let mut want = vec![0.0f32; rows * n];
    for r in 0..rows {
        matvec_hadamard_n(&x[r * n..(r + 1) * n], n, &mut want[r * n..(r + 1) * n]);
    }

    // the raw (scale = 1) transform keeps everything integer-valued
    let opts = FwhtOptions::raw();
    for kind in KernelKind::all() {
        let mut got = x.clone();
        fwht_f32(kind, &mut got, n, &opts);
        assert_eq!(got, want, "direct {kind:?} diverged from dense reference");
    }

    // the batched exec engine, sharded across lanes and chunk boundaries
    let engine = ExecEngine::new(ExecConfig {
        threads: 4,
        chunks_per_thread: 2,
        min_chunk_elems: 4096, // one row per chunk: both rows shard
        ..ExecConfig::default()
    });
    let mut got = x.clone();
    engine.run_f32(KernelKind::HadaCore, &mut got, n, &opts);
    assert_eq!(got, want, "engine diverged from dense reference");
    assert!(engine.stats().jobs > 0, "the batch must actually shard");
}

#[test]
fn engine_parity_across_the_npot_grid() {
    // direct kernel == sharded engine, bit for bit, at every base
    let engine = ExecEngine::new(ExecConfig {
        threads: 8,
        chunks_per_thread: 4,
        min_chunk_elems: 1024,
        ..ExecConfig::default()
    });
    let mut rng = Rng::new(0xB0);
    for (n, rows) in NPOT_SHAPES {
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);
        for kind in KernelKind::all() {
            let mut direct = x.clone();
            fwht_f32(kind, &mut direct, n, &opts);
            let mut sharded = x.clone();
            engine.run_f32(kind, &mut sharded, n, &opts);
            assert_eq!(direct, sharded, "kind={kind:?} n={n} rows={rows}");
        }
    }
}

#[test]
fn fused_depths_match_dense_reference_bit_for_bit_at_npot_sizes() {
    // the round-fusion acceptance bar on the npot grid: every pinned
    // depth — direct planned kernel AND sharded engine — must reproduce
    // the dense x @ H_n integer reference exactly
    use hadacore::exec::TunePolicy;
    use hadacore::hadamard::hadacore::{
        fwht_hadacore_f32_planned_depth, HadaCoreConfig, HadaCorePlan,
    };
    let mut rng = Rng::new(0xB4);
    for (n, rows) in [(768usize, 5usize), (5120, 3), (14336, 1)] {
        let x = integer_payload(&mut rng, rows * n);
        let mut want = vec![0.0f32; rows * n];
        for r in 0..rows {
            matvec_hadamard_n(&x[r * n..(r + 1) * n], n, &mut want[r * n..(r + 1) * n]);
        }
        let opts = FwhtOptions::raw();
        let plan = HadaCorePlan::new(n, &HadaCoreConfig::default());
        for depth in 1..=plan.max_fusion_depth() {
            let mut direct = x.clone();
            fwht_hadacore_f32_planned_depth(&mut direct, &plan, &opts, depth);
            assert_eq!(direct, want, "direct n={n} depth={depth}");

            let engine = ExecEngine::new(ExecConfig {
                threads: 4,
                chunks_per_thread: 2,
                min_chunk_elems: 1024,
                tune: TunePolicy::FixedDepth(depth),
            });
            let mut sharded = x.clone();
            engine.run_f32(KernelKind::HadaCore, &mut sharded, n, &opts);
            assert_eq!(sharded, want, "engine n={n} depth={depth}");
        }
    }
}

#[test]
fn engine_parity_npot_16bit() {
    use hadacore::hadamard::fwht_generic;
    use hadacore::util::f16::{Element, F16};
    let engine = ExecEngine::new(ExecConfig {
        threads: 4,
        chunks_per_thread: 2,
        min_chunk_elems: 1024,
        ..ExecConfig::default()
    });
    let mut rng = Rng::new(0xB1);
    for (n, rows) in [(768usize, 17usize), (14336, 3)] {
        let base: Vec<F16> = rng
            .normal_vec(rows * n)
            .iter()
            .map(|&v| F16::from_f32(v))
            .collect();
        let opts = FwhtOptions::normalized(n);
        let mut direct = base.clone();
        fwht_generic(KernelKind::HadaCore, &mut direct, n, &opts);
        let mut sharded = base;
        engine.run(KernelKind::HadaCore, &mut sharded, n, &opts);
        assert_eq!(direct, sharded, "n={n}");
    }
}

#[test]
fn fused_epilogues_bit_identical_at_npot_sizes() {
    // the fused rotate→quantize epilogue over the npot grid, including
    // 40·1024: per-tensor fp8 and grouped int8 (64 divides every B·2^k
    // here) must equal the unfused two-pass reference exactly
    let engine = ExecEngine::new(ExecConfig {
        threads: 4,
        chunks_per_thread: 2,
        min_chunk_elems: 2048,
        ..ExecConfig::default()
    });
    let mut rng = Rng::new(0xB2);
    for (n, rows) in NPOT_SHAPES {
        let x = rng.normal_vec(rows * n);
        let opts = FwhtOptions::normalized(n);

        let mut unfused = x.clone();
        engine.run_f32(KernelKind::HadaCore, &mut unfused, n, &opts);
        let mut fp8_ref = unfused.clone();
        let want_scale = fp8_quantize_slice(&mut fp8_ref, Fp8Format::E4M3);

        let mut fused = x.clone();
        let scales = engine.run_f32_with_epilogue(
            KernelKind::HadaCore,
            &mut fused,
            n,
            &opts,
            Epilogue::QuantFp8 { fmt: Fp8Format::E4M3 },
        );
        assert_eq!(scales, QuantScales::PerTensor(want_scale), "fp8 n={n}");
        assert_eq!(fp8_ref, fused, "fp8 n={n}");

        let group = 64;
        let mut int_ref = unfused;
        let want_scales = int_quantize_grouped(&mut int_ref, group, IntBits::Int8);
        let mut fused = x;
        let scales = engine.run_f32_with_epilogue(
            KernelKind::HadaCore,
            &mut fused,
            n,
            &opts,
            Epilogue::QuantInt8 { group },
        );
        assert_eq!(scales, QuantScales::PerGroup(want_scales), "int8 n={n}");
        assert_eq!(int_ref, fused, "int8 n={n}");
    }
}

#[test]
fn coordinator_serves_npot_sizes_end_to_end() {
    // admission, bucketing, batching, engine execution, and response
    // scatter for non-power-of-two sizes through the real serving path
    let coord = Coordinator::start(
        None,
        CoordinatorConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_delay: Duration::from_micros(200),
                work_conserving: true,
            },
            router: RouterConfig::default(),
            idle_timeout: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap();
    let mut rng = Rng::new(0xB3);
    let mut handles = Vec::new();
    let mut expected = Vec::new();
    for (id, n) in [(1u64, 768usize), (2, 5120), (3, 14336), (4, 768)] {
        let x = rng.normal_vec(n);
        let mut want = x.clone();
        fwht_f32(
            KernelKind::HadaCore,
            &mut want,
            n,
            &FwhtOptions::normalized(n),
        );
        expected.push(want);
        handles.push(coord.submit(TransformRequest::new(id, n, x)).unwrap());
    }
    for (h, want) in handles.into_iter().zip(expected.iter()) {
        let resp = h.recv().unwrap().unwrap();
        assert_eq!(resp.backend, "native");
        assert_close(&resp.data, want, 1e-3, 1e-3);
    }
    // and the rejection path names the family
    let err = coord
        .submit(TransformRequest::new(9, 11008, vec![0.0; 11008]))
        .unwrap_err();
    assert!(err.0.contains("12, 20, 28, 40"), "got: {}", err.0);
    coord.shutdown();
}
